"""Fleet subsystem: store views/durability, micro-batched service
parity + compile amortization, sharded-vs-single-device bit parity,
drift analytics, watchdog-on-store integration."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from _trace_utils import expect_traces

from repro.core.graph_data import build_graphs
from repro.core.model import PeronaConfig, PeronaModel
from repro.core.preprocess import Preprocessor
from repro.fingerprint.runner import SuiteRunner
from repro.fleet import (FingerprintStore, FleetScoringService,
                         degrading_nodes, drift_report, ewma_series)
from repro.runtime.watchdog import PeronaWatchdog
from repro.serving.engine import FingerprintEngine


@pytest.fixture(scope="module")
def setup():
    runner = SuiteRunner(seed=5)
    machines = {"f0": "e2-medium", "f1": "n2-standard-4",
                "f2": "e2-medium"}
    frame = runner.run_frame(machines, runs_per_type=10,
                             stress_fraction=0.2)
    pre = Preprocessor().fit(frame)
    batch = build_graphs(frame, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    params = model.init(jax.random.PRNGKey(0))  # untrained: scoring only
    return runner, machines, frame, pre, model, params


# ---------------------------------------------------------------- store

def test_store_views_match_naive_filtering(setup):
    _, _, frame, *_ = setup
    store = FingerprintStore()
    store.append(frame)
    f = store.frame
    t_lo, t_hi = float(np.quantile(f.t, 0.2)), float(np.quantile(f.t, 0.8))
    for node in f.machines:
        for btype in (None, "fio", "iperf3"):
            idx = store.view(node, btype, t_min=t_lo, t_max=t_hi)
            m = f.machine_code == f.machines.index(node)
            if btype is not None:
                m &= f.type_code == f.benchmark_types.index(btype)
            m &= (f.t >= t_lo) & (f.t <= t_hi)
            naive = np.nonzero(m)[0]
            naive = naive[np.lexsort((naive, f.t[naive]))]
            np.testing.assert_array_equal(idx, naive)


def test_store_newest_per_chain(setup):
    _, _, frame, *_ = setup
    store = FingerprintStore()
    store.append(frame)
    f = store.frame
    idx = store.view("f1", newest_per_chain=3)
    # 6 benchmark-type chains x newest 3
    assert len(idx) == 18
    for b in range(len(f.benchmark_types)):
        chain = np.nonzero((f.machine_code == f.machines.index("f1"))
                           & (f.type_code == b))[0]
        newest = chain[np.argsort(f.t[chain], kind="stable")][-3:]
        got = idx[f.type_code[idx] == b]
        assert set(got) == set(newest)


def test_store_append_ids_and_compact(setup):
    runner, machines, frame, *_ = setup
    store = FingerprintStore()
    first_a = store.append(frame)
    more = runner.run_frame(machines, runs_per_type=2)
    first_b = store.append(more)
    assert first_a == 0 and first_b == len(frame)
    assert len(store) == len(frame) + len(more)
    full = store.frame
    naive_keep = set()
    for mc in range(len(full.machines)):
        for bc in range(len(full.benchmark_types)):
            chain = np.nonzero((full.machine_code == mc)
                               & (full.type_code == bc))[0]
            naive_keep |= set(
                chain[np.argsort(full.t[chain], kind="stable")][-4:])
    kept_ids = set(store.row_id[sorted(naive_keep)])
    store.compact(per_chain=4)
    f = store.frame
    key = (f.machine_code.astype(np.int64) * len(f.benchmark_types)
           + f.type_code)
    _, counts = np.unique(key, return_counts=True)
    assert counts.max() <= 4
    # exactly the t-newest 4 per chain survive, ids intact, t-sorted
    assert set(store.row_id) == kept_ids
    assert np.all(np.diff(f.t) >= 0)


def test_store_save_load_roundtrip(setup, tmp_path):
    _, _, frame, pre, model, params = setup
    engine = FingerprintEngine(model, params, pre)
    store = FingerprintStore()
    store.append(frame)
    res = engine.score(store.frame)
    store.attach(np.arange(len(frame)), res.anomaly_prob, res.codes)
    path = os.path.join(tmp_path, "store.npz")
    store.save(path)
    loaded = FingerprintStore.load(path)
    assert len(loaded) == len(store)
    np.testing.assert_array_equal(loaded.row_id, store.row_id)
    np.testing.assert_array_equal(loaded.anomaly, store.anomaly)
    np.testing.assert_array_equal(loaded.codes, store.codes)
    assert loaded.frame.machines == store.frame.machines
    np.testing.assert_array_equal(loaded.frame.metrics,
                                  store.frame.metrics)
    # appends continue from the persisted id counter
    assert loaded.append(frame.select(np.arange(3))) == len(store)


def test_store_save_is_atomic_under_interruption(setup, tmp_path,
                                                 monkeypatch):
    """A crash mid-save must never corrupt an existing store file:
    the write goes to a temp file in the same directory and only an
    ``os.replace`` publishes it."""
    import repro.fleet.store as store_mod

    _, _, frame, *_ = setup
    store = FingerprintStore()
    store.append(frame)
    path = os.path.join(tmp_path, "store.npz")
    store.save(path)

    more = FingerprintStore()
    more.append(frame)
    more.append(frame.select(np.arange(5)))
    real_savez = np.savez_compressed

    def exploding_savez(fh, **payload):
        real_savez(fh, **{k: payload[k]
                          for k in list(payload)[: len(payload) // 2]})
        raise OSError("disk full")

    monkeypatch.setattr(store_mod.np, "savez_compressed",
                        exploding_savez)
    with pytest.raises(OSError, match="disk full"):
        more.save(path)
    monkeypatch.undo()
    # the original file is intact and loads; no temp litter remains
    loaded = FingerprintStore.load(path)
    assert len(loaded) == len(store)
    np.testing.assert_array_equal(loaded.frame.metrics,
                                  store.frame.metrics)
    assert [f for f in os.listdir(tmp_path)
            if f.endswith(".tmp")] == []


def test_store_rejects_mixed_feature_appends(setup):
    _, _, frame, pre, *_ = setup
    from repro.serving.engine import prepare_features

    store = FingerprintStore()
    store.append(frame)
    with pytest.raises(ValueError, match="mix"):
        store.append(frame, features=prepare_features(pre, frame))


# -------------------------------------------------------------- service

def test_service_matches_per_request_engine(setup):
    runner, machines, frame, pre, model, params = setup
    engine = FingerprintEngine(model, params, pre)
    svc = FleetScoringService(model, params, pre, context_per_chain=6,
                              sharded=False)
    svc.seed_history(frame)
    results = svc.score_round(runner.run_frame(machines,
                                               runs_per_type=2))
    assert sorted(results) == sorted(machines)
    for node, r in results.items():
        assert len(r.anomaly_prob) == 12  # 6 types x 2 runs
        assert len(r.context_row_ids) == 36  # 6 chains x 6 context
        # reference: score the same (context + new) rows through the
        # per-request engine path
        ids = np.concatenate([r.context_row_ids, r.row_ids])
        rows = np.nonzero(np.isin(svc.store.row_id, ids))[0]
        rows = rows[np.lexsort((rows, svc.store.frame.t[rows]))]
        ref = engine.score(svc.store.frame.select(rows))
        is_new = np.isin(svc.store.row_id[rows], r.row_ids)
        np.testing.assert_allclose(r.anomaly_prob,
                                   ref.anomaly_prob[is_new], atol=2e-5)
        np.testing.assert_allclose(r.codes, ref.codes[is_new],
                                   atol=2e-4)
        # scores persisted to the store
        rows = np.nonzero(np.isin(svc.store.row_id, r.row_ids))[0]
        assert not np.isnan(svc.store.anomaly[rows]).any()


def test_service_micro_batches_amortize_compile(setup):
    runner, machines, frame, pre, model, params = setup
    svc = FleetScoringService(model, params, pre, context_per_chain=6,
                              sharded=False)
    svc.seed_history(frame)
    with expect_traces(svc.scorer, 1):
        svc.score_round(runner.run_frame(machines, runs_per_type=2))
    assert svc.stats["dispatches"] == 1  # one bucket -> one dispatch
    # same request shapes -> no retracing in later flushes
    with expect_traces(svc.scorer, 0):
        for _ in range(3):
            svc.score_round(runner.run_frame(machines, runs_per_type=2))
    assert svc.stats["requests_served"] == 4 * len(machines)


def test_engine_donates_padded_inputs(setup):
    """Every padded input buffer (all args but params) is donated in
    both compiled scoring calls; repeated scoring keeps working since
    buffers are rebuilt from numpy per call."""
    from repro.fleet.shard import ShardedScorer
    from repro.serving.engine import ARG_NAMES

    _, _, frame, pre, model, params = setup
    engine = FingerprintEngine(model, params, pre)
    expected = tuple(range(1, 1 + len(ARG_NAMES)))
    assert engine.donate_argnums == expected
    scorer = ShardedScorer(model, pre, devices=jax.devices()[:1])
    assert scorer.donate_argnums == expected
    # repeated public scoring keeps working (buffers are rebuilt)
    r1 = engine.score(frame)
    r2 = engine.score(frame)
    np.testing.assert_array_equal(r1.anomaly_prob, r2.anomaly_prob)


def test_service_minimal_context_matches_full_history(setup):
    """Streaming rounds: the service's receptive-field context
    (P x tag_hops rows per chain) reproduces full-history rescoring
    exactly — the §III-C chain graph gives each execution a bounded
    ancestry."""
    runner, machines, frame, pre, model, params = setup
    engine = FingerprintEngine(model, params, pre)
    svc = FleetScoringService(model, params, pre, sharded=False)
    assert svc.context_per_chain == 6  # P=3 x tag_hops=2
    svc.seed_history(frame)
    rnd = runner.run_frame(machines, runs_per_type=2, t_offset=86400.0)
    results = svc.score_round(rnd)
    store = svc.store
    first = min(r.row_ids.min() for r in results.values())
    for node, r in results.items():
        # full-history reference: every stored row of this node
        rows = store.view(node)
        ref = engine.score(store.frame.select(rows))
        is_new = store.row_id[rows] >= first
        np.testing.assert_allclose(r.anomaly_prob,
                                   ref.anomaly_prob[is_new],
                                   rtol=0, atol=1e-6)


def test_service_burst_flush_matches_sequential(setup):
    """Coalescing several queued rounds into one flush produces the
    same scores as flushing round by round (ancestry closure)."""
    runner, machines, frame, pre, model, params = setup
    rounds = [SuiteRunner(seed=33).run_frame(
        machines, runs_per_type=1, t_offset=(k + 1) * 86400.0)
        for k in range(3)]

    seq = FleetScoringService(model, params, pre, sharded=False)
    seq.seed_history(frame)
    seq_probs = {n: [] for n in machines}
    for rnd in rounds:
        for n, r in seq.score_round(rnd).items():
            seq_probs[n].append(r.anomaly_prob)

    burst = FleetScoringService(model, params, pre, sharded=False)
    burst.seed_history(frame)
    for rnd in rounds:
        burst.submit(rnd)
    merged = burst.flush()
    assert burst.stats["flushes"] == 1
    for n in machines:
        np.testing.assert_allclose(
            merged[n].anomaly_prob, np.concatenate(seq_probs[n]),
            rtol=0, atol=1e-6)


def test_service_quarantines_invalid_telemetry(setup):
    """NaN/Inf rows and unfitted benchmark types never reach the store
    or the jitted scorer: they are quarantined with stats counters, the
    clean remainder scores normally."""
    import dataclasses

    from repro.common.rng import folded_generator
    from repro.fleet.faults import corrupt_frame

    runner, machines, frame, pre, model, params = setup
    svc = FleetScoringService(model, params, pre, sharded=False)
    svc.seed_history(frame)
    rnd = runner.run_frame(machines, runs_per_type=2,
                           t_offset=86400.0)
    bad, n_bad = corrupt_frame(rnd, folded_generator(0), n_cols=2,
                               row_fraction=0.3)
    assert n_bad > 0
    results = svc.score_round(bad)
    assert svc.stats["quarantined_nonfinite"] == n_bad
    assert svc.stats["quarantined_rows"] == n_bad
    scored = sum(len(r.anomaly_prob) for r in results.values())
    assert scored == len(rnd) - n_bad
    f = svc.store.frame
    assert np.isfinite(np.where(f.metrics_present, f.metrics,
                                0.0)).all()
    assert sum(len(q) for q in svc.quarantine) == n_bad

    # unfitted benchmark types are counted separately
    alien = dataclasses.replace(
        rnd, benchmark_types=("bogus",) + rnd.benchmark_types[1:])
    n_alien = int((alien.type_code == 0).sum())
    svc.submit(alien)
    assert svc.stats["quarantined_unknown_type"] == n_alien

    # the strict policy raises instead
    strict = FleetScoringService(model, params, pre, sharded=False,
                                 on_invalid="raise")
    with pytest.raises(ValueError, match="NaN/Inf"):
        strict.submit(bad)
    with pytest.raises(ValueError, match="unknown"):
        FleetScoringService(model, params, pre, on_invalid="bad-mode")


# ---------------------------------------------------------------- drift

def test_ewma_series_matches_recurrence():
    rng = np.random.default_rng(0)
    x = rng.random(50)
    alpha = 0.25
    got = ewma_series(x, alpha)
    acc = x[0]
    for i, v in enumerate(x):
        if i:
            acc = (1 - alpha) * acc + alpha * v
        assert got[i] == pytest.approx(acc)


def test_drift_report_over_store(setup):
    runner, machines, frame, pre, model, params = setup
    svc = FleetScoringService(model, params, pre, context_per_chain=6,
                              sharded=False)
    svc.seed_history(frame)
    for _ in range(3):
        svc.score_round(runner.run_frame(machines, runs_per_type=1))
    report = drift_report(svc.store)
    assert sorted(report) == sorted(machines)
    for d in report.values():
        assert d.n_scored == 18  # 3 rounds x 6 types
        assert 0.0 <= d.anomaly_ewma <= 1.0
        assert set(d.aspect_ewma) == {"cpu", "memory", "disk",
                                      "network"}
        assert all(v >= 0 for v in d.aspect_ewma.values())
    # degrading_nodes honors threshold + min history
    assert degrading_nodes(report, ewma_threshold=1.1) == {}
    assert sorted(degrading_nodes(report, ewma_threshold=0.0)) == \
        sorted(machines)


# ------------------------------------------------------------- watchdog

def test_watchdog_runs_on_store_and_reports_drift(setup):
    runner, machines, frame, pre, model, params = setup
    wd = PeronaWatchdog(model, params, pre, history_per_chain=6)
    wd.history = frame
    decisions = wd.observe(runner.run_frame(machines, runs_per_type=1))
    assert [d.node for d in decisions] == sorted(machines)
    assert all(np.isfinite(d.anomaly_ewma) for d in decisions)
    # new-round scores were attached to the store -> drift is queryable
    report = wd.drift_report()
    assert sorted(report) == sorted(machines)
    assert wd.store.frame is wd.history_frame


def test_watchdog_empty_round_and_fresh_store(setup):
    """An empty round on a history-less watchdog must not crash, in
    either scoring path."""
    _, machines, frame, pre, model, params = setup
    empty = frame.select(np.arange(0))
    wd = PeronaWatchdog(model, params, pre)
    assert wd.observe(empty) == []
    # with history present, an empty round costs no scoring dispatch
    wd.history = frame
    assert wd.observe(empty) == []
    assert wd.engine.trace_count == 0
    svc = FleetScoringService(model, params, pre, sharded=False)
    wd2 = PeronaWatchdog(model, params, pre, service=svc)
    assert wd2.observe(empty) == []
    assert wd2.history == []


def test_watchdog_through_fleet_service(setup):
    runner, machines, frame, pre, model, params = setup
    svc = FleetScoringService(model, params, pre, context_per_chain=6,
                              sharded=False)
    wd = PeronaWatchdog(model, params, pre, service=svc,
                        history_per_chain=6)
    wd.history = frame
    for _ in range(2):
        decisions = wd.observe(runner.run_frame(machines,
                                                runs_per_type=1))
        assert [d.node for d in decisions] == sorted(machines)
    assert wd.store is svc.store
    assert svc.stats["requests_served"] == 2 * len(machines)
    # engine-path and service-path watchdogs agree on the decisions
    wd2 = PeronaWatchdog(model, params, pre, history_per_chain=6)
    wd2.history = frame
    r2 = SuiteRunner(seed=99).run_frame(machines, runs_per_type=1)
    d_service = PeronaWatchdog(model, params, pre,
                               service=FleetScoringService(
                                   model, params, pre,
                                   context_per_chain=6, sharded=False),
                               history_per_chain=6)
    d_service.history = frame
    a = wd2.observe(r2)
    b = d_service.observe(r2)
    for da, db in zip(a, b):
        assert da.node == db.node
        assert da.flagged == db.flagged
        assert da.anomaly_prob == pytest.approx(db.anomaly_prob,
                                                abs=2e-5)


# ------------------------------------------------- sharded parity (slow)

@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_scoring_bit_identical_subprocess():
    """8 virtual CPU devices: shard_map'd fleet scoring must produce
    bit-identical scores to a single-device scorer."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from repro.core.graph_data import build_graphs
        from repro.core.model import PeronaConfig, PeronaModel
        from repro.core.preprocess import Preprocessor
        from repro.fingerprint.runner import SuiteRunner
        from repro.fleet import FleetScoringService
        from repro.fleet.shard import ShardedScorer

        assert jax.device_count() == 8
        runner = SuiteRunner(seed=2)
        machines = {f"s{i}": "e2-medium" for i in range(16)}
        frame = runner.run_frame(machines, runs_per_type=6,
                                 stress_fraction=0.2)
        pre = Preprocessor().fit(frame)
        batch = build_graphs(frame, pre)
        cfg = PeronaConfig(feature_dim=pre.feature_dim,
                           edge_dim=batch.edge.shape[-1])
        model = PeronaModel(cfg)
        params = model.init(jax.random.PRNGKey(0))

        def scores(devices):
            svc = FleetScoringService(model, params, pre,
                                      context_per_chain=4,
                                      devices=devices)
            svc.seed_history(frame)
            res = svc.score_round(
                SuiteRunner(seed=3).run_frame(machines, runs_per_type=1))
            return res, svc

        res8, svc8 = scores(jax.devices())
        res1, svc1 = scores(jax.devices()[:1])
        assert svc8.scorer.n_devices == 8
        assert svc1.scorer.n_devices == 1
        for node in res1:
            a, b = res8[node], res1[node]
            assert np.array_equal(a.anomaly_prob, b.anomaly_prob)
            assert np.array_equal(a.codes, b.codes)
            assert np.array_equal(a.type_logits, b.type_logits)
        print("OK bit-identical across", svc8.scorer.n_devices,
              "devices")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", code],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK bit-identical" in proc.stdout
