"""Runtime: straggler detection, Perona watchdog, fault-tolerant loop."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.manager import CheckpointManager
from repro.data.tokens import TokenPipeline
from repro.runtime.fault import FailureInjector, TrainingRuntime
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.watchdog import PeronaWatchdog


def test_straggler_monitor_flags_persistent_slow_host():
    mon = StragglerMonitor(ratio_threshold=1.3, patience=3)
    flagged = []
    for step in range(10):
        times = {"h0": 100.0, "h1": 100.0, "h2": 100.0, "h3": 250.0}
        flagged += mon.record_step(step, times)
    assert any(ev.host == "h3" for ev in flagged)
    assert not any(ev.host in ("h0", "h1", "h2") for ev in flagged)


def test_straggler_monitor_ignores_transient_blip():
    # a single 4x blip decays through the EWMA within ~5 steps
    # (log(1.3/4)/log(1-alpha) with alpha=0.3), so patience=6 must not
    # fire while patience=3 would — the knob separates transient
    # interference from persistent degradation
    mon = StragglerMonitor(ratio_threshold=1.3, patience=6, alpha=0.3)
    flagged = []
    for step in range(14):
        slow = 400.0 if step == 5 else 100.0
        flagged += mon.record_step(step, {"a": 100.0, "b": 100.0,
                                          "c": slow})
    assert not flagged


@pytest.fixture(scope="module")
def small_watchdog():
    from repro.core.graph_data import build_graphs
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.preprocess import Preprocessor
    from repro.core.trainer import train_perona
    from repro.fingerprint.runner import SuiteRunner

    runner = SuiteRunner(seed=11)
    machines = {"good-0": "n2-standard-4", "good-1": "n2-standard-4"}
    records = runner.run(machines, runs_per_type=40, stress_fraction=0.2)
    pre = Preprocessor().fit(records)
    batch = build_graphs(records, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    res = train_perona(model, batch, epochs=60, seed=2)
    wd = PeronaWatchdog(model, res.params, pre, confirm_runs=2)
    wd.history = list(records)
    return wd, runner, machines


def test_watchdog_confirms_degraded_node(small_watchdog):
    wd, runner, machines = small_watchdog
    # two consecutive fully-degraded fingerprint rounds on good-1
    for _ in range(2):
        recs = runner.run({"good-1": "n2-standard-4"}, runs_per_type=2,
                          degraded_machines=["good-1"])
        decisions = wd.observe(recs)
    assert "good-1" in wd.excluded_nodes()


def test_watchdog_passes_healthy_node(small_watchdog):
    wd, runner, machines = small_watchdog
    wd._strikes.clear()
    for _ in range(3):
        recs = runner.run({"good-0": "n2-standard-4"}, runs_per_type=2)
        wd.observe(recs)
    assert "good-0" not in wd.excluded_nodes()


def _runtime(tmp_path, fail_at=None, steps_between_ckpt=5):
    pipeline = TokenPipeline(vocab_size=64, seq_len=8, global_batch=2,
                             seed=0)
    seen_batches = []

    def init_state(hosts):
        return {"w": jnp.zeros(()), "n": jnp.zeros(())}

    def train_step(state, batch, hosts):
        seen_batches.append(int(np.asarray(batch["tokens"]).sum()))
        new = {"w": state["w"] + 1.0, "n": state["n"] + 1.0}
        return new, {"loss": float(new["w"])}

    rt = TrainingRuntime(
        hosts=["h0", "h1", "h2", "h3"], train_step=train_step,
        init_state=init_state, pipeline=pipeline,
        ckpt=CheckpointManager(tmp_path, async_save=False),
        checkpoint_every=steps_between_ckpt,
        failure_injector=FailureInjector(
            {fail_at: ["h2"]} if fail_at else None))
    return rt, seen_batches


def test_runtime_runs_to_completion(tmp_path):
    rt, _ = _runtime(tmp_path)
    out = rt.run(12)
    assert len(out["losses"]) == 12
    assert out["restarts"] == 0


def test_runtime_recovers_from_failure(tmp_path):
    rt, seen = _runtime(tmp_path, fail_at=8)
    out = rt.run(12)
    assert out["restarts"] == 1
    assert "h2" not in out["final_hosts"]
    # restored from step 5 checkpoint -> steps 6,7 replayed; the replayed
    # batches are identical to the originals (deterministic pipeline)
    assert any(ev.kind == "failure" for ev in out["events"])
    # final step count preserved: w == number of *effective* steps
    assert float(np.asarray(out["state"]["w"])) >= 12 - 1


def test_runtime_restart_resumes_from_checkpoint(tmp_path):
    rt, _ = _runtime(tmp_path)
    rt.run(11)  # checkpoints at 0,5,10
    rt2, _ = _runtime(tmp_path)
    out = rt2.run(12)  # should resume at 11, run one step
    assert any(ev.kind == "restart" for ev in out["events"])
    assert len(out["losses"]) == 1
