"""Optimizer + distributed-optimization-trick tests."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import AdamW, opt_state_specs, zero1_specs
from repro.optim.compress import compress_gradients, decompress_gradients
from repro.optim.schedule import cosine_schedule, linear_warmup


def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_clips_gradients():
    opt = AdamW(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.full(4, 100.0)}, state, params)
    assert float(m["grad_norm"]) == 200.0  # reported pre-clip


def test_weight_decay_skips_vectors():
    opt = AdamW(lr=0.1, weight_decay=1.0)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = opt.init(params)
    p2, _, _ = opt.update({"w": jnp.zeros((2, 2)), "b": jnp.zeros(2)},
                          state, params)
    assert float(p2["w"][0, 0]) < 1.0  # decayed
    assert float(p2["b"][0]) == 1.0  # not decayed


def test_zero1_specs_shard_first_divisible_dim():
    specs = {"w": P(None, "model"), "n": P()}
    aps = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
           "n": jax.ShapeDtypeStruct((7,), jnp.float32)}
    z = zero1_specs(specs, aps, data_axis="data", data_size=16)
    assert z["w"] == P("data", "model")
    assert z["n"] == P(None)  # 7 not divisible by 16 -> replicated


def test_opt_state_specs_structure():
    specs = {"w": P(None, "model")}
    aps = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
    os_ = opt_state_specs(specs, aps, zero1=True, data_axis="data",
                          data_size=16)
    assert os_.m["w"] == P("data", "model")
    assert os_.step == P()


def test_compression_error_feedback_unbiased():
    """EF property: quantization error is carried, so the *cumulative*
    applied gradient converges to the cumulative true gradient."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)) * 1e-3)
    err = None
    applied = jnp.zeros_like(g_true)
    for step in range(30):
        (q, s), err = compress_gradients({"g": g_true},
                                         err if err is None else err)
        deq = decompress_gradients(q, s)
        applied = applied + deq["g"]
    total_true = g_true * 30
    rel = float(jnp.linalg.norm(applied - total_true)
                / jnp.linalg.norm(total_true))
    assert rel < 0.05, rel


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_quantize_bounds_property(seed):
    rng = np.random.default_rng(seed)
    g = {"g": jnp.asarray(rng.normal(size=(64,)) * rng.uniform(1e-6, 1e3))}
    (q, s), _ = compress_gradients(g)
    assert q["g"].dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q["g"]))) <= 127
    deq = decompress_gradients(q, s)
    # error bounded by one quantization bucket
    assert float(jnp.max(jnp.abs(deq["g"] - g["g"]))) <= float(s["g"]) + 1e-9


def test_schedules():
    warm = linear_warmup(1.0, 10)
    assert float(warm(jnp.asarray(5))) == 0.5
    cos = cosine_schedule(1.0, 10, 100, final_frac=0.1)
    assert float(cos(jnp.asarray(0))) == 0.0
    assert float(cos(jnp.asarray(10))) == 1.0
    assert abs(float(cos(jnp.asarray(100))) - 0.1) < 1e-6
