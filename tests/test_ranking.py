"""core/ranking: aspect grouping, p-norm limits, degenerate machines."""

import numpy as np
import pytest

from repro.core.ranking import (ASPECT_OF_TYPE, aspect_scores,
                                code_scores, machine_score_vector,
                                rank_machines)


def test_code_scores_pnorm_approaches_max():
    rng = np.random.default_rng(0)
    codes = rng.normal(size=(32, 16))
    mx = np.abs(codes).max(-1)
    d = codes.shape[-1]
    # exact p-norm sandwich: max <= ||x||_p <= max * d^(1/p), so the
    # score converges to the max coordinate as p grows
    for p in (10.0, 50.0, 200.0):
        s = code_scores(codes, p=p)
        assert np.all(s >= mx - 1e-9)
        assert np.all(s <= mx * d ** (1.0 / p) + 1e-9)
    np.testing.assert_allclose(code_scores(codes, p=200.0), mx,
                               rtol=d ** (1.0 / 200.0) - 1 + 1e-6)
    # monotone: larger p never increases the score
    s10 = code_scores(codes, p=10.0)
    s50 = code_scores(codes, p=50.0)
    assert np.all(s50 <= s10 + 1e-9)


def test_aspect_scores_grouping_matches_aspect_of_type():
    types = list(ASPECT_OF_TYPE)
    machines = ["m0", "m1"]
    n = len(types) * len(machines)
    type_names = types * len(machines)
    machine_col = [m for m in machines for _ in types]
    rng = np.random.default_rng(1)
    codes = rng.normal(size=(n, 8))
    out = aspect_scores(codes, type_names, machine_col)
    assert sorted(out) == machines
    s = code_scores(codes)
    for m in machines:
        # every machine covers exactly the aspects of its types
        assert set(out[m]) == set(ASPECT_OF_TYPE.values())
        for aspect in set(ASPECT_OF_TYPE.values()):
            member = [s[i] for i in range(n)
                      if machine_col[i] == m
                      and ASPECT_OF_TYPE[type_names[i]] == aspect]
            assert out[m][aspect] == pytest.approx(np.mean(member))


def test_aspect_scores_single_benchmark_machine():
    """A machine with one execution of one type must not crash and
    reports only that type's aspect."""
    codes = np.asarray([[1.0, -2.0, 0.5]])
    out = aspect_scores(codes, ["fio"], ["lonely"])
    assert list(out) == ["lonely"]
    assert list(out["lonely"]) == ["disk"]
    assert out["lonely"]["disk"] == pytest.approx(
        float(code_scores(codes)[0]))
    # ranking / vector extraction handle the sparse aspect dict
    assert rank_machines(out) == ["lonely"]
    assert rank_machines(out, aspect="network") == ["lonely"]
    vec = machine_score_vector(out, "lonely")
    assert vec.shape == (4,)
    assert vec[2] > 0 and vec[0] == vec[1] == vec[3] == 0.0


def test_rank_machines_orders_by_aspect_and_mean():
    scores = {
        "fast-disk": {"disk": 3.0, "cpu": 1.0},
        "fast-cpu": {"disk": 1.0, "cpu": 3.5},
    }
    assert rank_machines(scores, aspect="disk")[0] == "fast-disk"
    assert rank_machines(scores, aspect="cpu")[0] == "fast-cpu"
    assert rank_machines(scores)[0] == "fast-cpu"  # higher mean
