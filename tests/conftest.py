"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — tests see the
real (single) device; multi-device sharding tests spawn subprocesses."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def paper_records():
    from repro.fingerprint.runner import paper_acquisition

    return paper_acquisition(seed=0)


@pytest.fixture(scope="session")
def fitted(paper_records):
    from repro.core.graph_data import build_graphs, chronological_split
    from repro.core.preprocess import Preprocessor

    train_r, val_r, test_r = chronological_split(paper_records)
    pre = Preprocessor().fit(train_r)
    return {
        "pre": pre,
        "train_records": train_r,
        "val_records": val_r,
        "test_records": test_r,
        "train": build_graphs(train_r, pre),
        "val": build_graphs(val_r, pre),
        "test": build_graphs(test_r, pre),
    }


@pytest.fixture(scope="session")
def trained_perona(fitted):
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.trainer import train_perona

    cfg = PeronaConfig(feature_dim=fitted["pre"].feature_dim,
                       edge_dim=fitted["train"].edge.shape[-1])
    model = PeronaModel(cfg)
    res = train_perona(model, fitted["train"], fitted["val"], epochs=80,
                       seed=0)
    return model, res.params
