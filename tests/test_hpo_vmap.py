"""Vmapped HPO buckets vs sequential per-trial training.

The vmapped search buckets trials by (heads, use_root_weight) and runs
one compiled vmapped scan per bucket; each trial's (val_f1, val_loss)
score must reproduce a plain sequential per-trial training, and the
engine must compile exactly once per occupied bucket.
"""

import numpy as np
import pytest
from _trace_utils import expect_traces

from repro.core import trainer as trainer_mod
from repro.core.graph_data import build_graphs, chronological_split
from repro.core.model import PeronaConfig
from repro.core.preprocess import Preprocessor
from repro.core.trainer import train_perona_reference
from repro.fingerprint.runner import SuiteRunner
from repro.tuning import hpo

N_TRIALS = 6
EPOCHS = 8


@pytest.fixture(scope="module")
def setup():
    runner = SuiteRunner(seed=7)
    machines = {"m0": "e2-medium", "m1": "n2-standard-4"}
    frame = runner.run_frame(machines, runs_per_type=10,
                             stress_fraction=0.2)
    tr, va, _ = chronological_split(frame, (0.7, 0.3, 0.0))
    pre = Preprocessor().fit(tr)
    tb, vb = build_graphs(tr, pre), build_graphs(va, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=tb.edge.shape[-1])
    return cfg, tb, vb


@pytest.fixture(scope="module")
def vmapped(setup):
    cfg, tb, vb = setup
    return hpo.search(cfg, tb, vb, n_trials=N_TRIALS, epochs=EPOCHS,
                      seed=0, return_stats=True)


def test_vmapped_reproduces_sequential_scores(setup, vmapped):
    cfg, tb, vb = setup
    best_v, trials_v, _ = vmapped
    best_s, trials_s = hpo.search(cfg, tb, vb, n_trials=N_TRIALS,
                                  epochs=EPOCHS, seed=0, vmapped=False)
    assert [t.params for t in trials_v] == [t.params for t in trials_s]
    for a, b in zip(trials_v, trials_s):
        np.testing.assert_allclose(a.val_f1, b.val_f1, atol=1e-6)
        np.testing.assert_allclose(a.val_loss, b.val_loss, atol=1e-4)
    assert best_v.params == best_s.params


def test_vmapped_close_to_legacy_reference_loop(setup, vmapped):
    """And against the pinned legacy per-epoch loop (host float64 F1,
    static hypers): F1 counts must agree exactly, losses closely."""
    cfg, tb, vb = setup
    _, trials_v, _ = vmapped
    _, trials_r = hpo.search_sequential(
        cfg, tb, vb, n_trials=N_TRIALS, epochs=EPOCHS, seed=0,
        train_fn=train_perona_reference)
    for a, b in zip(trials_v, trials_r):
        np.testing.assert_allclose(a.val_f1, b.val_f1, atol=1e-6)
        np.testing.assert_allclose(a.val_loss, b.val_loss, atol=2e-3)


def test_compiles_once_per_bucket(setup, vmapped):
    """<=8 compiled calls for any search: one vmapped scanned trainer
    per occupied (heads, use_root_weight) bucket — and zero new traces
    for a repeat search (compile caches are keyed on the canonical
    config + padded bucket size)."""
    cfg, tb, vb = setup
    _, _, stats = vmapped
    assert stats.n_buckets <= 8
    assert stats.device_calls == stats.n_buckets
    assert stats.trace_count == stats.n_buckets
    with expect_traces(trainer_mod.TRAINER_TRACES, 0):
        _, _, stats2 = hpo.search(cfg, tb, vb, n_trials=N_TRIALS,
                                  epochs=EPOCHS, seed=0,
                                  return_stats=True)
    assert stats2.trace_count == 0


def test_best_trial_has_trained_result(vmapped):
    best, trials, _ = vmapped
    assert best.result is not None
    assert best.score == max(t.score for t in trials)
    assert len(best.result.history) >= 1
    assert {"epoch", "train_loss", "val_loss",
            "val_f1_outlier"} <= set(best.result.history[0])
    # every non-best trial's result was freed / never materialized
    assert sum(t.result is not None for t in trials) == 1
