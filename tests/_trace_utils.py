"""Shared jit trace-count assertion helper.

Registers the compile-amortization pattern from ``test_engine.py`` (a
counter that increments at trace time only) for reuse: wrap the code
under test in :func:`expect_traces` and the helper asserts exactly how
many jit tracings happened inside the block.

Works with any counter object exposing ``trace_count``
(``serving.FingerprintEngine``, ``repro.obs.jaxstat.JitSite``),
``count`` (legacy trace counters, ``JitSite`` again) or ``value``
(a raw ``repro.obs.metrics.Counter`` pulled off the registry).
"""

import contextlib


def _read(counter) -> int:
    if hasattr(counter, "trace_count"):
        return counter.trace_count
    if hasattr(counter, "count"):
        return counter.count
    return int(counter.value)


@contextlib.contextmanager
def expect_traces(counter, n: int):
    """Assert exactly ``n`` jit tracings happen inside the block."""
    before = _read(counter)
    yield
    got = _read(counter) - before
    assert got == n, f"expected {n} jit tracings inside block, got {got}"
