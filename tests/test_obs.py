"""Unified telemetry plane: metrics registry semantics, span tracing
over injectable clocks, Chrome trace-event export/validation, JitSite
consolidation of the jit trace counters, disabled-mode no-ops, and the
two end-to-end timelines the PR promises — a daemon fault storm with
named ladder transitions, and a pipelined replay whose host table
builds overlap device block scans on separate thread tracks."""

import json
import threading

import jax
import numpy as np
import pytest

from repro import obs
from repro.obs import metrics as obs_metrics
from repro.obs.jaxstat import JitSite, instance_site
from repro.obs.timeline import (chrome_trace, validate_chrome_trace,
                                validate_chrome_trace_file,
                                write_chrome_trace)
from repro.obs.trace import (CAT_DEVICE, CAT_HOST, CAT_LADDER,
                             SpanEvent, Tracer)

from _trace_utils import expect_traces


# ---------------------------------------------------------- registry

def test_registry_identity_and_labels():
    reg = obs_metrics.MetricsRegistry()
    c1 = reg.counter("x.hits", site="a")
    c2 = reg.counter("x.hits", site="a")
    c3 = reg.counter("x.hits", site="b")
    assert c1 is c2 and c1 is not c3
    c1.inc()
    c1.inc(3)
    assert c1.value == 4 and c3.value == 0
    g = reg.gauge("x.depth")
    g.set(7.5)
    assert g.value == 7.5
    snap = reg.snapshot()
    assert snap["x.hits{site=a}"] == 4
    assert snap["x.hits{site=b}"] == 0
    assert snap["x.depth"] == 7.5
    assert "x.hits{site=a} 4" in reg.render()
    with pytest.raises(TypeError):
        reg.gauge("x.hits", site="a")  # same key, different type


def test_counter_thread_safety():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("threads.incs")
    n_threads, per = 8, 2000

    def work():
        for _ in range(per):
            c.inc()

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * per


def test_histogram_exact_path_matches_np_quantile():
    reg = obs_metrics.MetricsRegistry()
    h = reg.histogram("lat", exact_limit=4096)
    rng = np.random.default_rng(0)
    xs = rng.lognormal(0.0, 1.5, size=500)
    for x in xs:
        h.observe(x)
    assert h.exact
    for q in (0.5, 0.9, 0.99):
        assert h.quantile(q) == float(np.quantile(xs, q))
    assert h.count == 500
    assert h.sum == pytest.approx(float(xs.sum()))
    qs = h.quantiles((0.5, 0.99))
    assert set(qs) == {"p50", "p99"}


def test_histogram_folds_past_exact_limit():
    h = obs_metrics.Histogram("lat", exact_limit=64)
    rng = np.random.default_rng(1)
    xs = rng.lognormal(0.0, 1.0, size=1000)
    for x in xs:
        h.observe(x)
    assert not h.exact  # folded to log buckets
    # count/sum/min/max stay exact
    assert h.count == 1000
    assert h.sum == pytest.approx(float(xs.sum()))
    s = h.summary()
    assert s["min"] == float(xs.min()) and s["max"] == float(xs.max())
    # folded quantiles: base-2 buckets -> within a factor of sqrt(2)
    for q in (0.5, 0.99):
        exact = float(np.quantile(xs, q))
        assert h.quantile(q) == pytest.approx(exact, rel=0.5)


def test_histogram_empty_and_nonpositive():
    h = obs_metrics.Histogram("lat", exact_limit=2)
    assert np.isnan(h.quantile(0.5))
    h.observe(0.0)
    h.observe(-1.0)
    h.observe(3.0)  # folds (exact_limit=2 exceeded)
    assert not h.exact
    assert h.count == 3 and h.quantile(0.0) <= 0.0


# ---------------------------------------------------- disabled mode

def test_disabled_mode_noops_everything():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("off.hits")
    h = reg.histogram("off.lat")
    tr = Tracer()
    site = JitSite("off.site", registry=reg, tracer=tr)
    with obs.disabled():
        assert not obs.enabled()
        c.inc(5)
        h.observe(1.0)
        with tr.span("s"):
            pass
        tr.instant("i")
        tr.complete("c", CAT_HOST, 0.0, 1.0)
        with site.dispatch("d"):
            pass
    assert obs.enabled()  # restored
    assert c.value == 0 and h.count == 0
    assert tr.events() == []
    assert site.dispatches == 0
    assert site.compile_seconds == 0.0 and site.run_seconds == 0.0
    # re-enabled: everything records again
    c.inc()
    with tr.span("s2"):
        pass
    assert c.value == 1 and len(tr.events()) == 1


# ------------------------------------------------------------ tracer

def test_tracer_spans_and_injectable_clock():
    clock = {"t": 10.0}
    tr = Tracer(clock=lambda: clock["t"])
    with tr.span("work", cat=CAT_HOST, args={"k": 1}):
        clock["t"] = 12.5
    (ev,) = tr.events()
    assert (ev.name, ev.cat, ev.ts, ev.dur) == ("work", CAT_HOST,
                                                10.0, 2.5)
    assert ev.args == {"k": 1}
    assert ev.thread == threading.current_thread().name
    tr.instant("mark", CAT_LADDER, ts=11.0)
    tr.complete("flush", CAT_HOST, ts=10.5, dur=0.25)
    assert [e.name for e in tr.events()] == ["work", "mark", "flush"]
    tr.clear()
    assert tr.events() == [] and tr.dropped == 0


def test_tracer_ring_bounds_and_drop_count():
    tr = Tracer(max_events=4)
    for i in range(7):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) == 4 and evs[0].name == "e3"
    assert tr.dropped == 3


# ---------------------------------------------------------- timeline

def test_chrome_trace_export_is_valid_and_monotonic(tmp_path):
    clock = {"t": 0.0}
    tr = Tracer(clock=lambda: clock["t"])
    with tr.span("outer"):
        clock["t"] = 1.0
    tr.instant("ladder.block", CAT_LADDER, ts=0.5)
    with tr.span("later", cat=CAT_DEVICE):
        clock["t"] = 3.0
    path = str(tmp_path / "t.json")
    obj = write_chrome_trace(path, tracer=tr, process_name="test-proc")
    summary = validate_chrome_trace_file(path)
    assert summary["spans"] == 2 and summary["threads"] == 1
    evs = obj["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {"process_name", "thread_name",
            "thread_sort_index"} <= {e["name"] for e in meta}
    timed = [e for e in evs if e["ph"] != "M"]
    # microseconds relative to the earliest event, monotonic per track
    assert [e["ts"] for e in timed] == [0.0, 500_000.0, 1_000_000.0]
    xs = [e for e in timed if e["ph"] == "X"]
    assert xs[0]["dur"] == 1_000_000.0 and xs[1]["dur"] == 2_000_000.0
    inst = next(e for e in timed if e["ph"] == "i")
    assert inst["s"] == "t" and inst["cat"] == CAT_LADDER
    with open(path) as f:
        assert json.load(f) == obj  # artifact round-trips


def test_chrome_trace_interleaves_threads_deterministically():
    events = [
        SpanEvent("a", CAT_HOST, 0.0, 1.0, tid=111, thread="main"),
        SpanEvent("b", CAT_DEVICE, 0.5, 1.0, tid=222, thread="worker"),
        SpanEvent("c", CAT_HOST, 2.0, 0.5, tid=111, thread="main"),
    ]
    obj = chrome_trace(events)
    validate_chrome_trace(obj)
    names = {e["args"]["name"] for e in obj["traceEvents"]
             if e.get("name") == "thread_name"}
    assert names == {"main", "worker"}
    # dense tids in first-seen order: main -> 0, worker -> 1
    by_name = {e["name"]: e["tid"] for e in obj["traceEvents"]
               if e["ph"] == "X"}
    assert by_name == {"a": 0, "b": 1, "c": 0}


def test_validator_rejects_malformed_traces():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"nope": []})
    base = [{"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "p"}},
            {"ph": "M", "pid": 1, "tid": 0, "name": "thread_name",
             "args": {"name": "t"}}]

    def bad(*evs):
        with pytest.raises(ValueError) as ei:
            validate_chrome_trace({"traceEvents": base + list(evs)})
        return str(ei.value)

    assert "unknown phase" in bad(
        {"ph": "Z", "pid": 1, "tid": 0, "name": "x", "ts": 0})
    assert "goes backwards" in bad(
        {"ph": "X", "pid": 1, "tid": 0, "name": "x", "ts": 5, "dur": 1},
        {"ph": "X", "pid": 1, "tid": 0, "name": "y", "ts": 4, "dur": 1})
    assert "dur" in bad(
        {"ph": "X", "pid": 1, "tid": 0, "name": "x", "ts": 0})
    assert "no open B" in bad(
        {"ph": "E", "pid": 1, "tid": 0, "name": "x", "ts": 0})
    assert "unclosed B" in bad(
        {"ph": "B", "pid": 1, "tid": 0, "name": "x", "ts": 0})
    assert "does not match" in bad(
        {"ph": "B", "pid": 1, "tid": 0, "name": "x", "ts": 0},
        {"ph": "E", "pid": 1, "tid": 0, "name": "y", "ts": 1})
    assert "thread_name" in bad(
        {"ph": "X", "pid": 1, "tid": 9, "name": "x", "ts": 0, "dur": 0})
    # matched B/E with metadata passes
    validate_chrome_trace({"traceEvents": base + [
        {"ph": "B", "pid": 1, "tid": 0, "name": "x", "ts": 0},
        {"ph": "E", "pid": 1, "tid": 0, "name": "x", "ts": 1}]})


# ------------------------------------------------------------ JitSite

def test_jitsite_attributes_compile_vs_run():
    reg = obs_metrics.MetricsRegistry()
    tr = Tracer()
    site = JitSite("t.site", registry=reg, tracer=tr)
    with site.dispatch("call", args={"n": 1}):
        site.tick()  # traced inside the call -> compile time
    with site.dispatch("call", args={"n": 2}):
        pass  # warm -> run time
    assert site.count == site.trace_count == 1
    assert site.dispatches == 2
    assert site.compile_seconds > 0.0 and site.run_seconds > 0.0
    evs = tr.events()
    assert [e.cat for e in evs] == [CAT_DEVICE, CAT_DEVICE]
    assert evs[0].args["traced"] is True
    assert evs[1].args["traced"] is False
    st = site.stats()
    assert st["traces"] == 1 and st["dispatches"] == 2
    assert reg.snapshot()["jax.traces{site=t.site}"] == 1


def test_instance_site_labels_are_unique():
    a, b = instance_site("x.y"), instance_site("x.y")
    assert a != b and a.startswith("x.y/")


def test_expect_traces_reads_jitsite_and_raw_counter():
    reg = obs_metrics.MetricsRegistry()
    site = JitSite("e.site", registry=reg)
    with expect_traces(site, 2):
        site.tick()
        site.tick()
    raw = reg.counter("e.raw")
    with expect_traces(raw, 1):
        raw.inc()


# --------------------------------------- engine/trainer consolidation

def test_engine_trace_count_backed_by_registry():
    """FingerprintEngine's trace_count survives the consolidation: the
    per-instance registry counter advances exactly when the engine
    retraces, and dispatch/compile accounting rides along."""
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.preprocess import Preprocessor
    from repro.fingerprint.runner import SuiteRunner
    from repro.serving.engine import FingerprintEngine

    runner = SuiteRunner(seed=3)
    frame = runner.run_frame({"m-0": "e2-medium"}, runs_per_type=4)
    pre = Preprocessor().fit(frame)
    from repro.core.graph_data import build_graphs
    batch = build_graphs(frame, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    engine = FingerprintEngine(model, model.init(jax.random.PRNGKey(0)),
                               pre)
    assert engine.trace_count == 0
    engine.score(frame)
    assert engine.trace_count == 1
    engine.score(frame)  # same bucket: no retrace
    assert engine.trace_count == 1
    assert engine.jit.dispatches == 2
    assert engine.jit.compile_seconds > 0.0
    key = f"jax.traces{{site={engine.jit.site}}}"
    assert obs.registry().snapshot()[key] == 1


# --------------------------------------------- end-to-end timelines

MACHINES = {"ob-0": "e2-medium", "ob-1": "n2-standard-4",
            "ob-2": "e2-medium"}


@pytest.fixture(scope="module")
def setup():
    from repro.core.graph_data import build_graphs
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.preprocess import Preprocessor
    from repro.fingerprint.runner import SuiteRunner

    runner = SuiteRunner(seed=5)
    frame = runner.run_frame(MACHINES, runs_per_type=10,
                             stress_fraction=0.2)
    pre = Preprocessor().fit(frame)
    batch = build_graphs(frame, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    params = model.init(jax.random.PRNGKey(0))  # untrained: scoring only
    return frame, pre, model, params


def _storm_daemon(setup):
    from repro.fleet import (FleetScoringService, IngestionDaemon,
                             fleet_telemetry)

    frame, pre, model, params = setup
    svc = FleetScoringService(model, params, pre, sharded=False)
    svc.seed_history(frame)
    daemon = IngestionDaemon(svc, capacity_rows=48, flush_interval=10.0,
                             flush_rows=1 << 30, min_flush_gap=5.0,
                             degrade_after=2, recover_after=1,
                             degrade_sample_per_chain=1,
                             service_time_scale=0.0)
    events = fleet_telemetry(MACHINES, rounds=8, runs_per_type=2,
                             seed=13, interval=0.05, jitter=0.01)
    return daemon, events


def test_daemon_fault_storm_timeline(setup, tmp_path):
    """A backpressure storm exports a perfetto-loadable timeline whose
    ladder transitions (block -> shed -> degrade) are named instant
    events on the daemon's virtual clock, alongside the flush spans."""
    from repro.fleet import fleet_telemetry

    daemon, events = _storm_daemon(setup)
    daemon.run(events)  # gated consumer: shed + degrade
    # phase 2: free the consumer so an overflow *blocks* (forces a
    # flush) instead of shedding — all three ladder steps in one run
    daemon.min_flush_gap = 0.0
    import dataclasses
    more = [dataclasses.replace(e, uid=e.uid + 100_000)
            for e in fleet_telemetry(MACHINES, rounds=4,
                                     runs_per_type=2, seed=19,
                                     interval=0.05, jitter=0.01)]
    daemon.run(more)
    st = daemon.stats()
    assert st["shed_rows"] > 0 and st["degrade_entries"] > 0
    assert st["forced_flushes"] > 0

    evs = daemon.tracer.events()
    names = [e.name for e in evs]
    for step in ("ladder.block", "ladder.shed", "ladder.degrade"):
        assert step in names, f"missing {step} in {sorted(set(names))}"
    ladder = [e for e in evs if e.cat == CAT_LADDER]
    assert all(e.ph == "i" for e in ladder)
    flushes = [e for e in evs if e.name == "ingest.flush"]
    assert len(flushes) == (st["forced_flushes"] + st["drain_flushes"]
                            + st["deadline_flushes"]
                            + st["row_trigger_flushes"])
    assert {f.args["trigger"] for f in flushes} >= {"forced", "drain"}
    assert any(f.args["degraded"] for f in flushes)
    # virtual clock: timestamps follow the daemon's `now`, not wall
    assert max(e.ts for e in evs) <= st["virtual_now"] + 1e-9

    path = str(tmp_path / "storm.json")
    write_chrome_trace(path, tracer=daemon.tracer)
    summary = validate_chrome_trace_file(path)
    assert summary["spans"] >= len(flushes)
    with open(path) as f:
        exported = {e.get("name") for e in json.load(f)["traceEvents"]}
    assert {"ladder.block", "ladder.shed", "ladder.degrade",
            "ingest.flush"} <= exported


def test_daemon_latency_histogram_parity(setup):
    """stats() keeps its latency_p50/p99 keys, now read from the shared
    streaming histogram — exact np.quantile over the recorded
    arrival->flush latencies while under the retention limit."""
    daemon, events = _storm_daemon(setup)
    daemon.run(events)
    st = daemon.stats()
    lat = daemon._latency
    assert lat.exact  # small run: exact-quantile regime
    assert st["latency_p50"] == daemon.latency_quantiles()["p50"]
    assert np.isfinite(st["latency_p99"])
    assert st["latency_p50"] <= st["latency_p99"]
    key = f"ingest.queue_latency_s{{daemon={daemon.site}}}"
    snap = obs.registry().snapshot()
    assert snap[key]["count"] == lat.count > 0


def test_daemon_core_stats_survive_disabled_plane(setup):
    """Program-logic counters (shed/dedup/flush accounting) are plain
    ints, NOT registry instruments: the ladder keeps exact counts even
    with the telemetry plane off, while spans/mirrors go quiet."""
    daemon, events = _storm_daemon(setup)
    with obs.disabled():
        daemon.run(events)
    st = daemon.stats()
    assert st["shed_rows"] > 0 and st["degrade_entries"] > 0
    assert st["events_seen"] == len(events)
    assert daemon.tracer.events() == []  # no spans recorded
    assert daemon._m_events.value == 0  # mirror stayed quiet


def test_pipelined_replay_host_device_overlap(tmp_path):
    """replay_pipelined's host table-build spans (main thread) overlap
    the device block-scan spans (per-device worker threads) on the
    process tracer — the pipelining is visible in the exported
    timeline as intersecting intervals on different thread tracks."""
    from repro.optimizer import (HEALTHY, build_scenarios,
                                 replay_pipelined)
    from repro.tuning.scout import ScoutDataset, VM_TYPES, \
        WORKLOAD_NAMES

    ds = ScoutDataset(seed=0)
    rng = np.random.default_rng(3)
    scores = {vm: {a: float(rng.uniform(0.5, 2.0))
                   for a in ("cpu", "memory", "disk", "network")}
              for vm in VM_TYPES}
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:2],
                            seeds=(0, 1), conditions=(HEALTHY,))
    tr = obs.tracer()
    tr.clear()
    traces = replay_pipelined(ds, scens, scores, block_lanes=4)
    assert len(traces) == len(scens)

    evs = tr.events()
    builds = [e for e in evs if e.name == "replay.build_tables"]
    scans = [e for e in evs if e.name == "replay.block_scan"]
    assert len(builds) == len(scans) == len(scens) // 4
    assert all(e.cat == CAT_DEVICE for e in scans)
    # worker-thread device track(s) distinct from the main host track
    assert {e.tid for e in scans}.isdisjoint({e.tid for e in builds})
    overlap = any(
        b.ts < s.ts + s.dur and s.ts < b.ts + b.dur
        for b in builds for s in scans)
    assert overlap, "no host build span overlapped a device scan span"

    path = str(tmp_path / "pipe.json")
    write_chrome_trace(path, tracer=tr)
    summary = validate_chrome_trace_file(path)
    assert summary["threads"] >= 2


def test_service_stats_traces_through_registry(setup):
    """fleet service stats()['traces'] reads the consolidated JitSite;
    quarantine mirrors land on the registry with kind labels."""
    from repro.fleet import FleetScoringService

    frame, pre, model, params = setup
    svc = FleetScoringService(model, params, pre, sharded=False)
    svc.seed_history(frame)
    assert svc.stats["traces"] == svc.scorer.jit.count
    site = svc.scorer.jit.site
    snap = obs.registry().snapshot()
    assert f"fleet.quarantined{{kind=nonfinite,site={site}}}" in snap
