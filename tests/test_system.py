"""End-to-end behaviour: the paper's §IV-C reproduction targets."""

import numpy as np
import pytest


def test_acquisition_scale(paper_records):
    # 3 nodes x 6 types x 100 runs = 1800, ~20% stressed
    assert len(paper_records) == 1800
    frac = np.mean([r.stressed for r in paper_records])
    assert 0.15 < frac < 0.25
    assert len({r.machine for r in paper_records}) == 3
    assert len({r.benchmark_type for r in paper_records}) == 6


def test_metric_reduction(fitted):
    pre = fitted["pre"]
    # paper: 153 raw -> 54 selected; simulated suite: ~159 raw, and the
    # selection must discard a substantial fraction (constants + echoes)
    assert 140 <= pre.raw_feature_count <= 175
    assert pre.n_selected < pre.raw_feature_count - 40
    assert pre.feature_dim == pre.n_selected + 6


def test_split_stratified(fitted):
    # every node appears in every split (paper's node stratification)
    for key in ("train_records", "val_records", "test_records"):
        assert len({r.machine for r in fitted[key]}) == 3


def test_paper_quality_targets(trained_perona, fitted):
    from repro.core.trainer import evaluate

    model, params = trained_perona
    m = evaluate(model, params, fitted["test"])
    # paper: MSE ~0.01, type acc 100%, F1(normal) 0.93, F1(outlier) 0.75,
    # weighted acc 90% — thresholds leave margin for seed variation
    assert m["mse"] <= 0.03, m
    assert m["type_accuracy"] >= 0.98, m
    assert m["f1_normal"] >= 0.90, m
    assert m["f1_outlier"] >= 0.65, m
    assert m["weighted_accuracy"] >= 0.85, m


def test_codes_cluster_by_type(trained_perona, fitted):
    """TML objective: same-type codes closer (cosine) than cross-type."""
    from repro.core.trainer import batch_to_jnp

    model, params = trained_perona
    out = model.forward(params, batch_to_jnp(fitted["test"]), train=False)
    codes = np.asarray(out["codes"])
    types = fitted["test"].type_id
    c = codes / np.maximum(
        np.linalg.norm(codes, axis=-1, keepdims=True), 1e-9)
    sim = c @ c.T
    same = types[:, None] == types[None, :]
    np.fill_diagonal(same, False)
    intra = sim[same].mean()
    inter = sim[~same].mean()
    # codes share a dominant direction (inputs live in (0,1)), so the
    # cosine gap is modest — but type clusters are linearly separable
    # (test_paper_quality_targets asserts the 100% linear probe)
    assert intra > inter + 0.05, (intra, inter)


def test_ranking_orders_machines_by_capability(trained_perona):
    """Ranking: faster machine types must receive higher scores."""
    from repro.core.graph_data import build_graphs
    from repro.core.ranking import aspect_scores, rank_machines
    from repro.core.trainer import batch_to_jnp
    from repro.fingerprint.runner import SuiteRunner
    from repro.core.preprocess import Preprocessor
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.trainer import train_perona

    # stress injection aids orientation detection (paper §III-B:
    # "Occasionally injecting synthetic stress into running benchmarks
    # further helps in identifying the orientation of a metric")
    runner = SuiteRunner(seed=3)
    machines = {"slow": "e2-medium", "fast": "c2-standard-4"}
    records = runner.run(machines, runs_per_type=30, stress_fraction=0.15)
    pre = Preprocessor().fit(records)
    batch = build_graphs(records, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    res = train_perona(model, batch, epochs=60, seed=1)
    out = model.forward(res.params, batch_to_jnp(batch), train=False)
    scores = aspect_scores(np.asarray(out["codes"]),
                           [r.benchmark_type for r in records],
                           [r.machine for r in records])
    ranked = rank_machines(scores, aspect="cpu")
    assert ranked[0] == "fast", scores
