"""Streaming ingestion daemon + fault injection: no-fault bit parity
with the closed-loop service, exact dedup/quarantine accounting under
injected faults, the backpressure ladder (block -> shed -> degrade),
rolling-drift parity, crash-safe checkpointing, and the
watchdog-under-faults e2e (injected degradation is flagged, clean
nodes stay unflagged)."""

import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.graph_data import build_graphs
from repro.core.model import PeronaConfig, PeronaModel
from repro.core.preprocess import Preprocessor
from repro.fingerprint.runner import SuiteRunner
from repro.fleet import (FaultPlan, FleetScoringService, IngestionDaemon,
                         TelemetryEvent, drift_report, fleet_telemetry,
                         inject_faults, load_staging)

DAY = 86400.0
MACHINES = {"in-0": "e2-medium", "in-1": "n2-standard-4",
            "in-2": "e2-medium"}


@pytest.fixture(scope="module")
def setup():
    runner = SuiteRunner(seed=5)
    frame = runner.run_frame(MACHINES, runs_per_type=10,
                             stress_fraction=0.2)
    pre = Preprocessor().fit(frame)
    batch = build_graphs(frame, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    params = model.init(jax.random.PRNGKey(0))  # untrained: scoring only
    return frame, pre, model, params


def _service(setup):
    frame, pre, model, params = setup
    svc = FleetScoringService(model, params, pre, sharded=False)
    svc.seed_history(frame)
    return svc


def _store_is_finite(store):
    f = store.frame
    return bool(
        np.isfinite(np.where(f.metrics_present, f.metrics, 0.0)).all()
        and np.isfinite(np.where(f.node_metrics_present,
                                 f.node_metrics, 0.0)).all()
        and np.isfinite(f.t).all())


# ------------------------------------------------------- no-fault parity

def test_daemon_no_faults_bit_identical_to_closed_loop(setup):
    """A fault-free daemon (one deadline flush per telemetry round)
    reproduces the closed-loop ``score_round`` scores bit for bit, and
    its incremental RollingDrift state equals the batch
    ``drift_report`` over the store exactly."""
    frame, pre, model, params = setup
    rounds = 3

    ref = _service(setup)
    src = SuiteRunner(seed=7)
    ref_results = {}
    for k in range(rounds):
        rnd = src.run_frame(MACHINES, runs_per_type=1,
                            t_offset=(k + 1) * DAY)
        for n, r in ref.score_round(rnd).items():
            ref_results.setdefault(n, []).append(r)

    svc = _service(setup)
    daemon = IngestionDaemon(svc, capacity_rows=512, flush_interval=0.5,
                             flush_rows=1 << 30, service_time_scale=0.0)
    events = fleet_telemetry(MACHINES, rounds=rounds, runs_per_type=1,
                             seed=7, interval=1.0, jitter=0.01)
    res = daemon.run(events)
    st = daemon.stats()
    assert st["deadline_flushes"] == rounds - 1
    assert st["drain_flushes"] == 1
    assert sorted(res) == sorted(MACHINES)
    for n in MACHINES:
        assert len(res[n]) == rounds
        for got, want in zip(res[n], ref_results[n]):
            np.testing.assert_array_equal(got.anomaly_prob,
                                          want.anomaly_prob)
            np.testing.assert_array_equal(got.codes, want.codes)
            np.testing.assert_array_equal(got.type_logits,
                                          want.type_logits)

    rolling = daemon.drift.report()
    batch = drift_report(svc.store, alpha=daemon.drift.alpha)
    assert sorted(rolling) == sorted(batch)
    for n in batch:
        assert rolling[n].n_scored == batch[n].n_scored
        assert rolling[n].anomaly_ewma == batch[n].anomaly_ewma
        assert rolling[n].anomaly_mean == batch[n].anomaly_mean
        assert rolling[n].aspect_ewma == batch[n].aspect_ewma
        assert rolling[n].aspect_mean == batch[n].aspect_mean
        assert rolling[n].last_t == batch[n].last_t


# -------------------------------------------------- faults + accounting

def test_daemon_dedup_and_quarantine_exact_under_faults(setup):
    """Against the injector's ground-truth FaultLog: every duplicated
    uid is dropped exactly once, every corrupted row is quarantined
    (none reaches the store or the scorer), and surviving rows are
    conserved: store rows = history + deduped stream - corrupted."""
    frame, *_ = setup
    events = fleet_telemetry(MACHINES, rounds=6, runs_per_type=2,
                             seed=11, interval=1.0, jitter=0.2)
    faulty, log = inject_faults(events, FaultPlan(
        seed=3, dropout=0.1, delay=0.3, duplicate=0.3, reorder=0.2,
        corrupt=0.3, burst=0.25, burst_window=2.0,
        stalls=(("in-1", 1.0, 4.0),)))
    assert log.duplicated and log.corrupted and log.dropped

    svc = _service(setup)
    daemon = IngestionDaemon(svc, capacity_rows=256,
                             flush_interval=0.5, flush_rows=64,
                             service_time_scale=0.0)
    daemon.run(faulty)
    st = daemon.stats()
    assert st["duplicates_dropped"] == len(log.duplicated)
    assert svc.stats["quarantined_nonfinite"] == log.corrupted_rows
    assert svc.stats["quarantined_unknown_type"] == 0
    assert _store_is_finite(svc.store)
    assert st["peak_staged_rows"] <= 256
    # conservation over the deduped stream (duplicates carry the same
    # uid; every surviving row is either quarantined or stored)
    deduped_rows = sum(len(e.frame) for u, e in
                      {e.uid: e for e in faulty}.items())
    assert len(svc.store) == (len(frame) + deduped_rows
                              - log.corrupted_rows - st["shed_rows"])
    # quarantined rows were never scored: all stored rows that carry a
    # score are finite, and the quarantine holds the poisoned ones
    q_rows = sum(len(f) for f in svc.quarantine)
    assert q_rows == log.corrupted_rows


def test_injector_is_deterministic():
    events = fleet_telemetry(MACHINES, rounds=4, seed=19, jitter=0.3)
    plan = FaultPlan(seed=8, dropout=0.2, delay=0.4, duplicate=0.3,
                     reorder=0.3, corrupt=0.4, burst=0.3)
    out1, log1 = inject_faults(events, plan)
    out2, log2 = inject_faults(list(events), plan)
    assert log1.counts() == log2.counts()
    assert [e.uid for e in out1] == [e.uid for e in out2]
    assert [e.arrival for e in out1] == [e.arrival for e in out2]
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(a.frame.metrics, b.frame.metrics)


# ---------------------------------------------------- backpressure ladder

def test_backpressure_block_step_forces_flush(setup):
    """Ladder step 1: with an always-available consumer
    (min_flush_gap=0) an arrival that would overflow the ring forces a
    flush instead of shedding — nothing is lost."""
    events = fleet_telemetry(MACHINES, rounds=4, runs_per_type=2,
                             seed=13, interval=0.05, jitter=0.01)
    svc = _service(setup)
    daemon = IngestionDaemon(svc, capacity_rows=48, flush_interval=10.0,
                             flush_rows=1 << 30, min_flush_gap=0.0,
                             service_time_scale=0.0)
    daemon.run(events)
    st = daemon.stats()
    assert st["forced_flushes"] > 0
    assert st["shed_rows"] == 0
    assert st["peak_staged_rows"] <= 48
    rows_in = sum(len(e.frame) for e in events)
    assert svc.stats["store_rows"] == len(setup[0]) + rows_in


def test_backpressure_shed_and_degrade_under_storm(setup):
    """Ladder steps 2+3: a gated consumer (min_flush_gap) under a
    storm sheds oldest-per-chain rows, then enters degraded sampled
    scoring; ring stays bounded and every row is accounted for."""
    frame, *_ = setup
    events = fleet_telemetry(MACHINES, rounds=8, runs_per_type=2,
                             seed=13, interval=0.05, jitter=0.01)
    svc = _service(setup)
    daemon = IngestionDaemon(svc, capacity_rows=48, flush_interval=10.0,
                             flush_rows=1 << 30, min_flush_gap=5.0,
                             degrade_after=2, recover_after=1,
                             degrade_sample_per_chain=1,
                             service_time_scale=0.0)
    daemon.run(events)
    st = daemon.stats()
    assert st["peak_staged_rows"] <= 48
    assert st["shed_rows"] > 0
    assert st["degrade_entries"] > 0 and st["degraded_flushes"] > 0
    assert st["degrade_unscored_rows"] > 0
    rows_in = sum(len(e.frame) for e in events)
    # shed rows are the only loss; degraded-mode unsampled rows are
    # stored (unscored), sampled rows are stored + scored
    assert len(svc.store) == len(frame) + rows_in - st["shed_rows"]
    assert svc.stats["rows_scored"] < rows_in


def test_shed_keeps_newest_rows_per_chain(setup):
    """Shedding drops the *oldest* rows of each (node x type) chain:
    after a storm the newest telemetry timestamps survive in staging
    or the store, the dropped ones are the early ones."""
    frame, *_ = setup
    events = fleet_telemetry(MACHINES, rounds=6, runs_per_type=2,
                             seed=17, interval=0.05)
    svc = _service(setup)
    daemon = IngestionDaemon(svc, capacity_rows=40, flush_interval=1e9,
                             flush_rows=1 << 30, min_flush_gap=1e9,
                             service_time_scale=0.0)
    daemon.run(events, drain=False)
    st = daemon.stats()
    assert st["shed_rows"] > 0 and st["staged_rows"] <= 40
    staged_t = np.concatenate(
        [s.frame.t for s in daemon._staged])
    # the newest round's timestamps all survived the shedding
    newest_round_t0 = 6 * DAY  # t0=DAY + (rounds-1)*DAY
    n_newest = sum(len(e.frame) for e in events
                   if e.frame.t.min() >= newest_round_t0)
    assert (staged_t >= newest_round_t0).sum() == n_newest


def test_degraded_mode_scores_newest_sample_per_chain(setup):
    """Degraded flushes score exactly the newest K rows per chain;
    the rest land in the store unscored (NaN anomaly)."""
    frame, *_ = setup
    svc = _service(setup)
    daemon = IngestionDaemon(svc, capacity_rows=512,
                             flush_interval=1e9, flush_rows=1 << 30,
                             degrade_sample_per_chain=1,
                             service_time_scale=0.0)
    daemon.degraded = True  # force ladder step 3
    events = fleet_telemetry(MACHINES, rounds=1, runs_per_type=3,
                             seed=23)
    for ev in events:
        daemon.offer(ev, now=ev.arrival)
    res = daemon.flush()
    st = daemon.stats()
    assert st["degraded_flushes"] == 1
    n_chains = len(MACHINES) * len(frame.benchmark_types)
    assert svc.stats["rows_scored"] == n_chains
    assert st["degrade_unscored_rows"] == n_chains * 2
    for n, r in res.items():
        assert len(r.anomaly_prob) == len(frame.benchmark_types)


# ------------------------------------------------------- flush triggers

def test_row_trigger_fires_on_pow2_bucket(setup):
    """Row-threshold flushes fire the moment staging reaches
    ``flush_rows`` (a pow2 bucket), before any deadline."""
    events = fleet_telemetry(MACHINES, rounds=4, runs_per_type=2,
                             seed=29, interval=1.0)
    per_round = sum(len(e.frame) for e in events) // 4
    svc = _service(setup)
    daemon = IngestionDaemon(svc, capacity_rows=1024,
                             flush_interval=1e9,
                             flush_rows=per_round,
                             service_time_scale=0.0)
    daemon.run(events)
    st = daemon.stats()
    assert st["row_trigger_flushes"] == 4
    assert st["deadline_flushes"] == 0
    # default flush_rows is a pow2 <= capacity
    d2 = IngestionDaemon(_service(setup), capacity_rows=100)
    assert d2.flush_rows == 64


def test_deadline_bounds_staging_latency(setup):
    """No staged row waits longer than flush_interval (+ service
    time): sparse arrivals still flush on the deadline."""
    events = fleet_telemetry(MACHINES, rounds=3, runs_per_type=1,
                             seed=31, interval=10.0)
    svc = _service(setup)
    daemon = IngestionDaemon(svc, capacity_rows=1024,
                             flush_interval=2.0, flush_rows=1 << 30,
                             service_time_scale=0.0)
    daemon.run(events, drain=False)
    daemon.advance(events[-1].arrival + 2.0 + 1e-6)
    st = daemon.stats()
    assert st["deadline_flushes"] == 3
    assert st["staged_rows"] == 0
    lat = daemon._latency.summary()  # shared obs histogram (exact max)
    assert lat["count"] > 0 and lat["max"] <= 2.0 + 1e-9


# ------------------------------------------------- crash-safe shutdown

def test_checkpoint_restore_resumes_identically(setup, tmp_path):
    """close(drain=False, checkpoint=...) + load_staging on a fresh
    daemon produces the same scores as a daemon that drained directly
    — accepted telemetry survives a restart exactly."""
    events = fleet_telemetry(MACHINES, rounds=2, runs_per_type=1,
                             seed=37, interval=1.0, jitter=0.05)

    svc_a = _service(setup)
    d_a = IngestionDaemon(svc_a, capacity_rows=512, flush_interval=1e9,
                          flush_rows=1 << 30, service_time_scale=0.0)
    res_a = d_a.run(events)  # drains on exit

    svc_b = _service(setup)
    d_b = IngestionDaemon(svc_b, capacity_rows=512, flush_interval=1e9,
                          flush_rows=1 << 30, service_time_scale=0.0)
    d_b.run(events, drain=False)  # crash with rows staged
    path = os.path.join(tmp_path, "staging.npz")
    d_b.close(drain=False, checkpoint=path)
    assert d_b.stats()["staged_rows"] == 0

    restored = load_staging(path)
    assert sorted(e.uid for e in restored) == \
        sorted(e.uid for e in events)
    svc_c = _service(setup)
    d_c = IngestionDaemon(svc_c, capacity_rows=512, flush_interval=1e9,
                          flush_rows=1 << 30, service_time_scale=0.0)
    res_c = d_c.run(restored)
    assert sorted(res_a) == sorted(res_c)
    for n in res_a:
        for ra, rc in zip(res_a[n], res_c[n]):
            np.testing.assert_array_equal(ra.anomaly_prob,
                                          rc.anomaly_prob)
            np.testing.assert_array_equal(ra.codes, rc.codes)


def test_close_drains_staged_rows(setup):
    frame, *_ = setup
    events = fleet_telemetry(MACHINES, rounds=1, runs_per_type=1,
                             seed=41)
    svc = _service(setup)
    daemon = IngestionDaemon(svc, capacity_rows=512, flush_interval=1e9,
                             flush_rows=1 << 30)
    for ev in events:
        daemon.offer(ev, now=ev.arrival)
    res = daemon.close(drain=True)
    assert sorted(res) == sorted(MACHINES)
    assert svc.stats["store_rows"] == len(frame) + sum(
        len(e.frame) for e in events)
    assert daemon.close() == {}  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        daemon.offer(events[0], now=0.0)


# ------------------------------------------------------ scorer failures

def test_transient_scorer_failure_retried_bit_identical(setup):
    """A scorer dispatch that fails once is retried (bounded, seeded
    backoff) and the run completes bit-identical to a clean one — the
    stacked host buffers survive the failed attempt."""
    ref = _service(setup)
    events = fleet_telemetry(MACHINES, rounds=3, runs_per_type=1,
                             seed=61, interval=1.0, jitter=0.01)
    ref_daemon = IngestionDaemon(ref, capacity_rows=512,
                                 flush_interval=0.5,
                                 flush_rows=1 << 30,
                                 service_time_scale=0.0)
    ref_res = ref_daemon.run(events)

    svc = _service(setup)
    svc.retry_backoff_s = 0.0  # don't sleep in tests
    real = svc.scorer.score_stack
    calls = {"n": 0}

    def flaky(params, stack):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient device loss")
        return real(params, stack)

    svc.scorer.score_stack = flaky
    daemon = IngestionDaemon(svc, capacity_rows=512,
                             flush_interval=0.5, flush_rows=1 << 30,
                             service_time_scale=0.0)
    res = daemon.run(events)
    st = daemon.stats()
    assert svc.stats["scorer_retries"] == 1
    assert st["scorer_retries"] == 1
    assert st["flush_failures"] == 0
    assert sorted(res) == sorted(ref_res)
    for n in ref_res:
        for got, want in zip(res[n], ref_res[n]):
            np.testing.assert_array_equal(got.anomaly_prob,
                                          want.anomaly_prob)
            np.testing.assert_array_equal(got.codes, want.codes)
    np.testing.assert_array_equal(svc.store.anomaly,
                                  ref.store.anomaly)


def test_terminal_scorer_failure_degrades_not_dies(setup):
    """When retries are exhausted the flush loses its scores, not the
    pipeline: the daemon keeps consuming the stream, rows stay durable
    (unscored) in the store, and the failure is counted + traced."""
    frame, *_ = setup
    svc = _service(setup)
    svc.dispatch_retries = 1
    svc.retry_backoff_s = 0.0

    def dead(params, stack):
        raise RuntimeError("device gone")

    svc.scorer.score_stack = dead
    events = fleet_telemetry(MACHINES, rounds=2, runs_per_type=1,
                             seed=62, interval=1.0, jitter=0.01)
    daemon = IngestionDaemon(svc, capacity_rows=512,
                             flush_interval=0.5, flush_rows=1 << 30,
                             service_time_scale=0.0)
    res = daemon.run(events)  # must not raise
    st = daemon.stats()
    assert res == {}
    assert st["flush_failures"] >= 1
    # one retry per failed flush: the first bucket's dispatch burns
    # its single retry, then the raise aborts the flush
    assert svc.stats["scorer_retries"] == st["flush_failures"]
    # every streamed row landed in the store, unscored
    assert len(svc.store) == len(frame) + sum(
        len(e.frame) for e in events)
    assert np.isnan(svc.store.anomaly[len(frame):]).all()
    names = [e.name for e in daemon.tracer.events()]
    assert "ingest.flush_failed" in names
    assert not daemon.degraded  # failure != backpressure degradation


# --------------------------------------------------------- threaded mode

def test_threaded_serve_smoke(setup):
    """Wall-clock mode: a poll source drains into the daemon thread,
    rounds get scored, close() joins the thread cleanly."""
    frame, *_ = setup
    events = fleet_telemetry(MACHINES, rounds=2, runs_per_type=1,
                             seed=43, interval=0.05)
    pending = list(events)
    lock = threading.Lock()

    def poll(now):
        with lock:
            due = [e for e in pending if e.arrival <= now]
            for e in due:
                pending.remove(e)
            return due

    svc = _service(setup)
    daemon = IngestionDaemon(svc, capacity_rows=512,
                             flush_interval=0.2, flush_rows=1 << 30,
                             service_time_scale=0.0)
    daemon.attach_source(poll)
    daemon.serve(poll_interval=0.02)
    deadline = time.time() + 30.0
    while time.time() < deadline:
        with lock:
            empty = not pending
        if empty and daemon.stats()["staged_rows"] == 0 \
                and daemon.results():
            break
        time.sleep(0.05)
    daemon.close(drain=True)
    assert daemon._thread is None
    res = daemon.results()
    assert sorted(res) == sorted(MACHINES)
    total = sum(len(r.anomaly_prob) for rs in res.values() for r in rs)
    assert total == sum(len(e.frame) for e in events)


# ------------------------------------------- watchdog under faults (e2e)

@pytest.fixture(scope="module")
def trained():
    from repro.core.trainer import train_perona

    # a deeper history + longer schedule than the scoring-path fixture:
    # the e2e needs a model that actually separates stressed telemetry
    runner = SuiteRunner(seed=11)
    frame = runner.run_frame(MACHINES, runs_per_type=40,
                             stress_fraction=0.2)
    pre = Preprocessor().fit(frame)
    batch = build_graphs(frame, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    res = train_perona(model, batch, epochs=120, seed=2)
    return frame, pre, model, res.params


def test_watchdog_flags_injected_degradation_under_faults(trained):
    """E2e: telemetry with one genuinely degraded node (stress-response
    shifted metrics) plus stream faults still drives the daemon's
    rolling drift to flag the degraded node within a few rounds, while
    clean nodes stay unflagged and the store stays finite."""
    frame, pre, model, params = trained
    rounds = 5
    events = fleet_telemetry(MACHINES, rounds=rounds, runs_per_type=2,
                             seed=47, interval=1.0, jitter=0.1,
                             degraded={"in-1": 1})
    faulty, log = inject_faults(events, FaultPlan(
        seed=9, delay=0.2, duplicate=0.2, corrupt=0.15, reorder=0.2))
    svc = FleetScoringService(model, params, pre, sharded=False)
    svc.seed_history(frame)
    daemon = IngestionDaemon(svc, capacity_rows=1024,
                             flush_interval=0.5, flush_rows=1 << 30,
                             service_time_scale=0.0)
    daemon.run(faulty)
    flagged = daemon.flagged_nodes(ewma_threshold=0.5, min_scored=3)
    assert "in-1" in flagged, (
        f"injected degradation not flagged; report="
        f"{ {n: round(d.anomaly_ewma, 3) for n, d in daemon.drift.report().items()} }")
    assert "in-0" not in flagged and "in-2" not in flagged
    assert _store_is_finite(svc.store)
    if log.corrupted:
        assert svc.stats["quarantined_rows"] == log.corrupted_rows
