"""Tuning stack: scout sim, GP, CherryPick/Arrow (+Perona), Lotaru,
Tarema — the paper's §IV-D/E integration claims."""

import numpy as np
import pytest

from repro.tuning.arrow import Arrow
from repro.tuning.cherrypick import CherryPick
from repro.tuning.gp import GP, expected_improvement
from repro.tuning.scout import ScoutDataset, WORKLOAD_NAMES


@pytest.fixture(scope="module")
def ds():
    return ScoutDataset(seed=0)


@pytest.fixture(scope="module")
def machine_scores():
    from repro.tuning.perona_weights import fingerprint_machine_scores

    return fingerprint_machine_scores(
        ("m4.large", "m4.xlarge", "m4.2xlarge", "c4.large", "c4.xlarge",
         "c4.2xlarge", "r4.large", "r4.xlarge", "r4.2xlarge"),
        runs_per_type=10, epochs=40, return_calibration=True)


def test_scout_dataset_shape(ds):
    # 18 workloads x 69 configurations = 1242 runs (paper §IV-D)
    assert len(ds.configs) == 69
    assert len(ds.workloads) == 18
    assert len(ds.configs) * len(ds.workloads) == 1242


def test_scout_runtimes_scale_sanely(ds):
    from repro.tuning.scout import CloudConfig

    wl = WORKLOAD_NAMES[0]
    small = ds.runtime_s(wl, CloudConfig("m4.large", 4))
    big = ds.runtime_s(wl, CloudConfig("m4.2xlarge", 4))
    assert big < small  # more cores -> faster
    assert ds.cost_usd(wl, CloudConfig("m4.large", 4)) > 0


def test_gp_interpolates_training_points():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(20, 3))
    y = np.sin(X[:, 0]) + X[:, 1] ** 2
    gp = GP(noise=1e-6).fit(X, y)
    mu, sigma = gp.predict(X)
    np.testing.assert_allclose(mu, y, atol=1e-2)
    assert np.all(sigma < 0.2)


def test_expected_improvement_prefers_low_mean_high_var():
    ei = expected_improvement(np.asarray([1.0, 0.1, 1.0]),
                              np.asarray([0.1, 0.1, 2.0]), best=0.5)
    assert ei[1] > ei[0]
    assert ei[2] > ei[0]


def test_cherrypick_finds_valid_config(ds):
    wl = WORKLOAD_NAMES[1]
    rts = [ds.runtime_s(wl, c) for c in ds.configs]
    limit = float(np.percentile(rts, 40))
    trace = CherryPick(ds, limit, seed=0).search(wl)
    assert trace.best_valid_cost[-1] < np.inf
    assert len(trace.evaluated) <= 9
    # found config actually satisfies the constraint
    costs = [(c, co, r) for c, co, r in
             zip(trace.evaluated, trace.costs, trace.runtimes)
             if r <= limit]
    assert min(co for _, co, _ in costs) == trace.best_valid_cost[-1]


def test_perona_weighting_no_worse_on_average(ds, machine_scores):
    """Fig-5 claim: Perona-weighted acquisition finds configurations at
    least as cheap (median over workloads) by the final profiling run."""
    from repro.tuning.perona_weights import PeronaAcquisitionWeighter

    scores, _ = machine_scores
    weighter = PeronaAcquisitionWeighter(ds, scores)
    base_final, perona_final = [], []
    for wl in WORKLOAD_NAMES[:6]:
        rts = [ds.runtime_s(wl, c) for c in ds.configs]
        limit = float(np.percentile(rts, 40))
        t0 = CherryPick(ds, limit, seed=1).search(wl)
        t1 = CherryPick(ds, limit, seed=1,
                        acquisition_weighter=weighter).search(wl)
        base_final.append(t0.best_valid_cost[-1])
        perona_final.append(t1.best_valid_cost[-1])
    assert np.median(perona_final) <= np.median(base_final) * 1.05


def test_arrow_perona_uses_scores_before_any_run(ds, machine_scores):
    from repro.core.ranking import machine_score_vector

    scores, _ = machine_scores
    low_fn = lambda wl, c: machine_score_vector(scores, c.vm_type)
    wl = WORKLOAD_NAMES[2]
    rts = [ds.runtime_s(wl, c) for c in ds.configs]
    limit = float(np.percentile(rts, 40))
    trace = Arrow(ds, limit, low_level_fn=low_fn, seed=0).search(wl)
    assert trace.best_valid_cost[-1] < np.inf


def test_lotaru_tableIII_ordering(machine_scores):
    """Benchmark-based predictors must beat naive/online baselines, and
    Perona must land within ~2x of Lotaru (paper: +1.74% median)."""
    from repro.tuning import lotaru
    from repro.tuning.perona_weights import calibrate_scores, \
        fingerprint_machine_scores

    scores, proxies = fingerprint_machine_scores(
        ("e2-medium", "n1-standard-4", "n2-standard-4", "c2-standard-4"),
        runs_per_type=10, epochs=40, return_calibration=True)
    cal = calibrate_scores(scores, proxies)
    tab = lotaru.evaluate_predictors(cal)
    assert tab["lotaru"]["median"] < tab["naive"]["median"]
    assert tab["perona"]["median"] < tab["naive"]["median"]
    assert tab["perona"]["median"] < 2.0 * tab["lotaru"]["median"] + 0.02


def test_tarema_same_groups():
    from repro.tuning import tarema
    from repro.tuning.perona_weights import calibrate_scores, \
        fingerprint_machine_scores

    scores, proxies = fingerprint_machine_scores(
        ("e2-medium", "n1-standard-4", "n2-standard-4", "c2-standard-4"),
        runs_per_type=10, epochs=40, return_calibration=True)
    cal = calibrate_scores(scores, proxies)
    machines = {"a": "n1-standard-4", "b": "n1-standard-4",
                "c": "n2-standard-4", "d": "c2-standard-4",
                "e": "e2-medium"}
    g_micro = tarema.groups_from_microbenchmarks(machines)
    g_perona = tarema.groups_from_perona(machines, cal)
    assert tarema.same_grouping(g_micro, g_perona)
