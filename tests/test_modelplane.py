"""Model management plane: registry durability + pinning, zero-downtime
hot-swap parity (identical candidate promoted mid-stream scores bit for
bit like a run that never swapped), automatic rollback + store repair
for a NaN-poisoned forced promote, canary rejection of a divergent
candidate, and the drift-triggered retrain -> canary -> promote loop."""

import os

import jax
import numpy as np
import pytest

from repro.core.graph_data import build_graphs
from repro.core.model import PeronaConfig, PeronaModel
from repro.core.preprocess import Preprocessor
from repro.fingerprint.runner import SuiteRunner
from repro.fleet import (FleetScoringService, IngestionDaemon,
                         ModelPlane, ModelRegistry, fleet_telemetry)

DAY = 86400.0
MACHINES = {"mp-0": "e2-medium", "mp-1": "n2-standard-4",
            "mp-2": "e2-medium"}


@pytest.fixture(scope="module")
def setup():
    runner = SuiteRunner(seed=5)
    frame = runner.run_frame(MACHINES, runs_per_type=10,
                             stress_fraction=0.2)
    pre = Preprocessor().fit(frame)
    batch = build_graphs(frame, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    params = model.init(jax.random.PRNGKey(0))  # untrained: scoring only
    return frame, pre, model, params


def _service(setup):
    frame, pre, model, params = setup
    svc = FleetScoringService(model, params, pre, sharded=False)
    svc.seed_history(frame)
    return svc


def _daemon(svc):
    return IngestionDaemon(svc, capacity_rows=512, flush_interval=0.5,
                           flush_rows=1 << 30, service_time_scale=0.0)


def _events(rounds, seed=7):
    return fleet_telemetry(MACHINES, rounds=rounds, runs_per_type=1,
                           seed=seed, interval=1.0, jitter=0.01)


def _plane(svc, daemon, tmp_path, **kw):
    kw.setdefault("canary_flushes", 1)
    kw.setdefault("watch_flushes", 2)
    kw.setdefault("min_health_shift", 1.0)  # only NaN should trip
    kw.setdefault("latency_budget", 100.0)  # not a wall-clock test
    return ModelPlane(svc, tmp_path / "registry", daemon=daemon, **kw)


def _assert_results_equal(got, want):
    assert sorted(got) == sorted(want)
    for n in want:
        assert len(got[n]) == len(want[n])
        for g, w in zip(got[n], want[n]):
            np.testing.assert_array_equal(g.anomaly_prob,
                                          w.anomaly_prob)
            np.testing.assert_array_equal(g.codes, w.codes)
            np.testing.assert_array_equal(g.type_logits, w.type_logits)
            np.testing.assert_array_equal(g.row_ids, w.row_ids)


# ------------------------------------------------------------- registry

def test_registry_roundtrip_and_crash_safety(tmp_path, monkeypatch):
    """Versions round-trip through a process restart; a crash while
    rewriting the index leaves the previous registry.json intact."""
    params = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
              "b": np.zeros(3, np.float32)}
    reg = ModelRegistry(tmp_path / "reg")
    v1 = reg.save_version(params, source="boot")
    reg.set_incumbent(v1)
    v2 = reg.save_version({"w": params["w"] * 2, "b": params["b"]},
                          source="retrain")
    reg.record_verdict(v2, {"passed": False,
                            "failed_checks": ["divergence"]})
    reg.tag(v1, "golden")

    reg2 = ModelRegistry(tmp_path / "reg")  # reload from disk
    assert reg2.incumbent == v1
    assert [e["version"] for e in reg2.list_versions()] == [v1, v2]
    assert reg2.entry(v1)["tags"] == ["golden"]
    assert reg2.entry(v2)["verdict"]["failed_checks"] == ["divergence"]
    got = reg2.load_version(params, v2)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  params["w"] * 2)

    # crash mid-rewrite: the checkpoint lands but the index swap fails
    before = reg2.list_versions()
    real_replace = os.replace

    def boom(src, dst, *a, **k):
        if str(dst).endswith("registry.json"):
            raise OSError("disk full")
        return real_replace(src, dst, *a, **k)

    monkeypatch.setattr("repro.fleet.modelplane.os.replace", boom)
    with pytest.raises(OSError):
        reg2.save_version(params, source="crash")
    monkeypatch.setattr("repro.fleet.modelplane.os.replace",
                        real_replace)
    reg3 = ModelRegistry(tmp_path / "reg")
    assert reg3.list_versions() == before
    assert reg3.incumbent == v1


def test_registry_pins_incumbent_against_gc(tmp_path):
    """keep-last GC never evicts the incumbent (or its predecessor),
    however many newer candidates pile up."""
    params = {"w": np.ones(4, np.float32)}
    reg = ModelRegistry(tmp_path / "reg", keep_last=1)
    v1 = reg.save_version(params, source="boot")
    reg.set_incumbent(v1)
    for k in range(3):
        last = reg.save_version({"w": params["w"] + k}, source="cand")
    got = reg.load_version(params, v1)  # pinned -> still on disk
    np.testing.assert_array_equal(np.asarray(got["w"]), params["w"])
    reg.load_version(params, last)  # newest unpinned survives
    with pytest.raises(FileNotFoundError):
        reg.load_version(params, last - 1)  # older candidate GC'd


# ------------------------------------------------------ hot-swap parity

def test_hot_swap_identical_candidate_is_invisible(setup, tmp_path):
    """An identical-parameters candidate canaried and promoted
    mid-stream changes nothing: every result and stored score is bit
    for bit equal to a run that never swapped, no event is dropped or
    double-scored, and the swap compiles nothing on the hot path."""
    frame, pre, model, params = setup
    rounds = 4

    ref_svc = _service(setup)
    ref_res = _daemon(ref_svc).run(_events(rounds))

    svc = _service(setup)
    daemon = _daemon(svc)
    plane = _plane(svc, daemon, tmp_path)
    plane.bootstrap(params)
    events = _events(rounds)
    k = len(events) // 2
    daemon.run(events[:k], drain=False)
    vid = plane.submit_candidate(params, source="test")
    res = daemon.run(events[k:], drain=True)

    _assert_results_equal(res, ref_res)
    np.testing.assert_array_equal(svc.store.anomaly,
                                  ref_svc.store.anomaly)
    assert len(svc.store) == len(ref_svc.store)
    st, ref_st = daemon.stats(), None
    assert st["events_seen"] == rounds * len(MACHINES)
    assert st["rows_staged_total"] == svc.stats["rows_scored"]
    assert svc.stats["rows_scored"] == ref_svc.stats["rows_scored"]
    # promoted exactly once, shadow-scored without touching the store,
    # and the candidate's programs were warm before the swap
    assert svc.stats["param_swaps"] == 1
    assert svc.stats["shadow_dispatches"] > 0
    assert svc.stats["warm_dispatches"] > 0
    assert svc.trace_count == ref_svc.trace_count  # zero new compiles
    assert plane.status()["promotions"] == 1
    assert plane.status()["rollbacks"] == 0
    assert plane.registry.incumbent == vid
    assert plane.registry.entry(vid)["verdict"]["passed"]


# -------------------------------------------------- automatic rollback

def test_nan_candidate_rolls_back_and_repairs(setup, tmp_path):
    """A NaN-producing candidate forced past the canary gate is rolled
    back by the health watch within bounded flushes; the store and the
    in-flight results end bit-identical to a run that never promoted,
    and the promote/rollback sequence is visible as tracer instants."""
    frame, pre, model, params = setup
    rounds = 4

    ref_svc = _service(setup)
    ref_res = _daemon(ref_svc).run(_events(rounds))

    svc = _service(setup)
    daemon = _daemon(svc)
    plane = _plane(svc, daemon, tmp_path, watch_flushes=3)
    v1 = plane.bootstrap(params)
    events = _events(rounds)
    k = len(events) // 2
    daemon.run(events[:k], drain=False)
    bad = jax.tree_util.tree_map(lambda x: np.asarray(x) * np.nan,
                                 params)
    vid = plane.registry.save_version(bad, source="bad")
    plane.promote(vid, force=True)
    res = daemon.run(events[k:], drain=True)

    st = plane.status()
    assert st["rollbacks"] == 1
    assert st["phase"] == "steady"
    assert st["repaired_rows"] > 0
    assert plane.registry.incumbent == v1
    assert plane.registry.entry(vid)["status"] == "rolled_back"

    # store + every returned result repaired to incumbent outputs
    _assert_results_equal(res, ref_res)
    np.testing.assert_array_equal(svc.store.anomaly,
                                  ref_svc.store.anomaly)
    # every row the reference run scored is finite here too — no NaN
    # leaked from the bad candidate (seeded history stays unscored)
    scored = np.isfinite(ref_svc.store.anomaly)
    assert np.isfinite(svc.store.anomaly[scored]).all()

    names = [e.name for e in daemon.tracer.events()]
    i_p = names.index("modelplane.promote")
    i_r = names.index("modelplane.rollback")
    assert i_p < i_r
    rb = daemon.tracer.events()[i_r]
    assert rb.args["reason"] == "nonfinite"
    assert rb.args["after_flushes"] <= 3


# ------------------------------------------------------------- canary

def test_canary_rejects_divergent_candidate(setup, tmp_path):
    """A candidate whose scores diverge past the budget never touches
    the live parameters; the verdict (with the failed checks) lands in
    the registry."""
    frame, pre, model, params = setup
    svc = _service(setup)
    daemon = _daemon(svc)
    plane = _plane(svc, daemon, tmp_path, canary_flushes=2)
    plane.bootstrap(params)
    events = _events(4)
    k = len(events) // 3
    daemon.run(events[:k], drain=False)
    divergent = jax.tree_util.tree_map(
        lambda x: np.asarray(x) * 10.0, params)
    vid = plane.submit_candidate(divergent, source="divergent")
    daemon.run(events[k:], drain=True)

    st = plane.status()
    assert st["canary_fail"] == 1
    assert st["promotions"] == 0
    assert svc.stats["param_swaps"] == 0
    entry = plane.registry.entry(vid)
    assert entry["status"] == "rejected"
    assert entry["verdict"]["passed"] is False
    assert "divergence" in entry["verdict"]["failed_checks"]
    assert entry["verdict"]["divergence_max"] > plane.divergence_budget
    names = [e.name for e in daemon.tracer.events()]
    assert "modelplane.canary_fail" in names
    assert "modelplane.promote" not in names


# ------------------------------------------------- drift retrain loop

def test_drift_triggers_retrain_canary_promote(setup, tmp_path):
    """Sustained degradation (threshold forced to zero) fires exactly
    one retrain episode; the retrained candidate flows through canary
    and is promoted with source attribution."""
    frame, pre, model, params = setup
    svc = _service(setup)
    daemon = _daemon(svc)
    retrained = []

    def retrain(service):
        retrained.append(len(service.store))
        return params  # identical params: canary must pass

    plane = _plane(svc, daemon, tmp_path, watch_flushes=1,
                   drift_flag_flushes=2, drift_ewma_threshold=0.0,
                   drift_min_scored=1, retrain_fn=retrain)
    plane.bootstrap(params)
    daemon.run(_events(4))

    st = plane.status()
    assert len(retrained) == 1
    assert st["retrains"] == 1
    assert st["promotions"] >= 1
    sources = {e["source"]: e for e in plane.registry.list_versions()}
    assert "drift-retrain" in sources
    assert sources["drift-retrain"]["status"] == "incumbent"
    assert sources["drift-retrain"]["extra"]["nodes"]
    names = [e.name for e in daemon.tracer.events()]
    assert "modelplane.retrain" in names
