"""Elastic checkpoint restore across cluster resizes: parameters saved
under one virtual-device mesh restore onto a differently-sized mesh
(``elastic_restore``) and score bit-identically — checkpoints hold full
host arrays, so the mesh is free to change between runs."""

import os
import subprocess
import sys
import textwrap

import pytest

_SAVE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    import jax
    import numpy as np
    from repro.checkpointing.manager import CheckpointManager
    from repro.core.graph_data import build_graphs
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.preprocess import Preprocessor
    from repro.fingerprint.runner import SuiteRunner
    from repro.fleet import FleetScoringService

    workdir = sys.argv[1]
    assert jax.device_count() == 4
    runner = SuiteRunner(seed=2)
    machines = {f"s{i}": "e2-medium" for i in range(8)}
    frame = runner.run_frame(machines, runs_per_type=6,
                             stress_fraction=0.2)
    pre = Preprocessor().fit(frame)
    batch = build_graphs(frame, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    params = model.init(jax.random.PRNGKey(0))

    mgr = CheckpointManager(os.path.join(workdir, "ckpt"),
                            async_save=False)
    mgr.save(1, params, extra={"saved_devices": jax.device_count()})

    svc = FleetScoringService(model, params, pre, context_per_chain=4)
    svc.seed_history(frame)
    res = svc.score_round(
        SuiteRunner(seed=3).run_frame(machines, runs_per_type=1))
    out = {}
    for node, r in res.items():
        out[node + ".anomaly"] = r.anomaly_prob
        out[node + ".codes"] = r.codes
        out[node + ".logits"] = r.type_logits
    np.savez(os.path.join(workdir, "ref_scores.npz"), **out)
    print("OK saved on", jax.device_count(), "devices")
""")

_RESTORE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax
    import numpy as np
    from jax.sharding import Mesh
    from repro.checkpointing.manager import CheckpointManager
    from repro.checkpointing.reshard import elastic_restore
    from repro.core.graph_data import build_graphs
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.preprocess import Preprocessor
    from repro.fingerprint.runner import SuiteRunner
    from repro.fleet import FleetScoringService

    workdir = sys.argv[1]
    assert jax.device_count() == 8
    runner = SuiteRunner(seed=2)
    machines = {f"s{i}": "e2-medium" for i in range(8)}
    frame = runner.run_frame(machines, runs_per_type=6,
                             stress_fraction=0.2)
    pre = Preprocessor().fit(frame)
    batch = build_graphs(frame, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    template = jax.tree_util.tree_map(
        lambda x: np.zeros_like(np.asarray(x)),
        model.init(jax.random.PRNGKey(1)))  # different seed: restore
                                            # must supply the values
    mgr = CheckpointManager(os.path.join(workdir, "ckpt"),
                            async_save=False)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    # spec_tree leaves that aren't PartitionSpecs resolve to
    # replicated placement — the right spec for scoring params
    restored, meta = elastic_restore(mgr, template, template, mesh)
    assert restored is not None
    assert meta["step"] == 1 and meta["saved_devices"] == 4

    svc = FleetScoringService(model, restored, pre,
                              context_per_chain=4)
    svc.seed_history(frame)
    res = svc.score_round(
        SuiteRunner(seed=3).run_frame(machines, runs_per_type=1))
    assert svc.scorer.n_devices == 8
    ref = np.load(os.path.join(workdir, "ref_scores.npz"))
    nodes = sorted({k.split(".")[0] for k in ref.files})
    assert sorted(res) == nodes
    for node in nodes:
        r = res[node]
        assert np.array_equal(r.anomaly_prob, ref[node + ".anomaly"])
        assert np.array_equal(r.codes, ref[node + ".codes"])
        assert np.array_equal(r.type_logits, ref[node + ".logits"])
    print("OK bit-identical after 4 -> 8 device elastic restore")
""")


def _run(code: str, workdir: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-c", code, workdir],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=420)


@pytest.mark.slow
@pytest.mark.multidevice
def test_elastic_restore_bit_identical_across_mesh_resize(tmp_path):
    """Save under a 4-device mesh, elastic-restore under an 8-device
    mesh: the resharded parameters score the same round bit for bit."""
    save = _run(_SAVE, str(tmp_path))
    assert save.returncode == 0, save.stderr[-2000:]
    assert "OK saved" in save.stdout
    restore = _run(_RESTORE, str(tmp_path))
    assert restore.returncode == 0, restore.stderr[-2000:]
    assert "OK bit-identical" in restore.stdout
