"""Correctness of §Perf optimization variants against baselines:
optimizations must not change the math (within quantization tolerance).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as moe_lib
from repro.models import nn
from repro.models import transformer as tfm
from repro.models.model_zoo import build_model


def test_einsum_moe_matches_scatter_moe():
    cfg = get_config("granite-moe-1b-a400m").scaled_down()
    moe_big = dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                  group_size=16)
    cfg = dataclasses.replace(cfg, moe=moe_big)
    init = nn.Init(jax.random.PRNGKey(0))
    params, _ = moe_lib.moe_init(init, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    o1, a1 = moe_lib.moe_apply_scatter(params, cfg, x)
    o2, a2 = moe_lib.moe_apply_einsum(params, cfg, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    assert float(jnp.abs(a1 - a2)) < 1e-6


def test_einsum_moe_grads_match():
    cfg = get_config("granite-moe-1b-a400m").scaled_down()
    moe_big = dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                  group_size=16)
    cfg = dataclasses.replace(cfg, moe=moe_big)
    init = nn.Init(jax.random.PRNGKey(0))
    params, _ = moe_lib.moe_init(init, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))

    g1 = jax.grad(lambda p: moe_lib.moe_apply_scatter(p, cfg, x)[0].sum())(
        params)
    g2 = jax.grad(lambda p: moe_lib.moe_apply_einsum(p, cfg, x)[0].sum())(
        params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4)


def test_causal_skip_matches_masked():
    from repro.models import attention as attn

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, hd = 1, 4096, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    a = attn.attend_chunked(q, k, v, pos, pos, causal=True, window=0,
                            scale=0.17, causal_skip=True)
    b = attn.attend_chunked(q, k, v, pos, pos, causal=True, window=0,
                            scale=0.17, causal_skip=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_kv_quant_decode_close_to_exact():
    """int8 KV cache must stay within quantization error of the exact
    decode path."""
    cfg = get_config("smollm-135m").scaled_down(dtype="float32")
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    model = build_model(cfg)
    model_q = build_model(cfg_q)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S + 1), 0,
                                cfg.vocab_size)
    c1 = model.init_cache(B, S + 4)
    c2 = model_q.init_cache(B, S + 4)
    l1, c1 = model.prefill(params, c1, tokens=tokens[:, :S])
    l2, c2 = model_q.prefill(params, c2, tokens=tokens[:, :S])
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=0.35,
                               rtol=0.1)
    pos = jnp.full((B,), S, jnp.int32)
    d1, _ = model.decode_step(params, tokens[:, S:], pos, c1)
    d2, _ = model_q.decode_step(params, tokens[:, S:], pos, c2)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=0.35,
                               rtol=0.1)
    # and argmax (the served token) agrees
    assert jnp.array_equal(jnp.argmax(d1, -1), jnp.argmax(d2, -1))


def test_dp_layout_strips_model_axis():
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import strip_model_axis

    tree = {"a": P(None, "model"), "b": P(("data", "model"), None),
            "c": P("data")}
    out = strip_model_axis(tree)
    assert out["a"] == P(None, None)
    assert out["b"] == P("data", None)
    assert out["c"] == P("data")


def test_mixed_precision_train_step_updates_f32_master():
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamW

    cfg = get_config("smollm-135m").scaled_down()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    state = opt.init(params)
    step = make_train_step(model, opt, compute_dtype="bfloat16")
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                     cfg.vocab_size),
    }
    new_params, new_state, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # master weights stay f32 and actually move
    leaf = jax.tree_util.tree_leaves(new_params)[0]
    assert leaf.dtype == jnp.float32
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved
